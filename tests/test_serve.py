"""Serving engine + egress-billed prefix cache."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serve.engine import Request, ServeEngine


def _engine(policy="gdsf"):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return ServeEngine(model, params, prefix_cache_bytes=1 << 22,
                       policy=policy), cfg


def test_serve_batch_produces_tokens():
    engine, cfg = _engine()
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    done = engine.serve(reqs)
    for r in done:
        assert r.output is not None and r.output.shape == (3,)
        assert (0 <= r.output).all() and (r.output < cfg.vocab_size).all()


def test_greedy_decode_deterministic():
    engine, cfg = _engine()
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    a = engine.serve([Request(0, prompt, 4)])[0].output
    b = engine.serve([Request(1, prompt.copy(), 4)])[0].output
    np.testing.assert_array_equal(a, b)


def test_prefix_cache_reduces_billing():
    engine, cfg = _engine()
    rng = np.random.default_rng(2)
    hot = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    # first serve stores the prefix; repeats hit the local egress cache
    for i in range(5):
        engine.serve([Request(i, hot, 2)])
    rep = engine.audit()
    assert rep.requests >= 4        # prefix touched on every repeat
    assert rep.hit_rate > 0.5
    assert rep.observed_dollars >= 0


def test_mixed_lengths_batched_by_length():
    engine, cfg = _engine()
    rng = np.random.default_rng(3)
    reqs = [Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 2),
            Request(1, rng.integers(0, cfg.vocab_size, 16).astype(np.int32), 2),
            Request(2, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 2)]
    done = engine.serve(reqs)
    assert all(r.output is not None for r in done)

def test_fleet_mode_partitions_prefix_cache():
    """fleet_nodes>0 shards the prefix cache across hash-partitioned
    hosts with their own meters; the engine's audit becomes per-host and
    the governance snapshot carries the fleet state."""
    import math

    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, prefix_cache_bytes=1 << 22,
                         policy="lru", fleet_nodes=3, governor_window=4)
    rng = np.random.default_rng(7)
    hot = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
           for _ in range(3)]
    rid = 0
    for _ in range(4):
        engine.serve([Request(rid + i, h, 2) for i, h in enumerate(hot)])
        rid += len(hot)
    fleet = engine.fleet
    assert engine.cache is None and fleet is not None
    assert sum(n.cache.hits + n.cache.misses for n in fleet.nodes) >= 9
    audits = engine.audit()
    assert set(audits) == {n.host for n in fleet.nodes}
    # realized fleet bill == fsum of per-host audits, bit-for-bit
    observed = math.fsum(a.observed_dollars for a in audits.values()
                         if a is not None)
    assert fleet.dollars() == observed
    snap = engine.governance_snapshot()
    assert snap["fleet"]["n_nodes"] == 3
    assert snap["fleet"]["dollars"] == fleet.dollars()
