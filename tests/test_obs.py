"""Observability layer (DESIGN.md §9): span tracer, decision event log,
histograms/Prometheus, solver profiling — and the billing-faithfulness
acceptance: summed span dollars == the consumer's BillingMeter total."""
import json
import re

import numpy as np
import pytest

from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore
from repro.obs import (EVENT_KINDS, EventLog, MetricsRegistry, NullTracer,
                       Tracer, log_bounds, regime_tag, sstar_bounds, validate)

# ---------------------------------------------------------------------------
# tracer


def test_span_nesting_parent_ids():
    t = Tracer()
    with t.span("a") as a:
        with t.span("b") as b:
            with t.span("c") as c:
                pass
    spans = {s.name: s for s in t.spans()}
    assert spans["a"].parent_id is None
    assert spans["b"].parent_id == spans["a"].span_id
    assert spans["c"].parent_id == spans["b"].span_id
    # closed innermost-first (complete events)
    assert [s.name for s in t.spans()] == ["c", "b", "a"]
    assert all(s.dur >= 0 for s in t.spans())


def test_span_begin_end_fast_path_matches_with():
    t = Tracer()
    sp = t.begin("outer", "cat1")
    inner = t.begin("inner", "cat1")
    t.end(inner)
    t.end(sp)
    assert inner.parent_id == sp.span_id
    assert t.spans(cat="cat1", name="inner")[0] is inner


def test_tracer_ring_is_bounded():
    t = Tracer(max_spans=10)
    for i in range(25):
        with t.span(f"s{i}"):
            pass
    assert len(t.spans()) == 10
    assert t.dropped == 15
    assert [s.name for s in t.spans()] == [f"s{i}" for i in range(15, 25)]


def test_dollars_query_fsum_with_filters():
    t = Tracer()
    for consumer, d in [("a", 0.1), ("a", 0.2), ("b", 0.4)]:
        with t.span("store.get", cat="store", consumer=consumer) as sp:
            sp.set(dollars=d)
    assert t.dollars(name="store.get", consumer="a") == pytest.approx(0.3)
    assert t.dollars() == pytest.approx(0.7)


def test_chrome_trace_round_trips_json():
    t = Tracer()
    with t.span("req", cat="serve", rid=7):
        with t.span("get", cat="cache") as sp:
            sp.set(bytes=123, dollars=1e-6)
    blob = json.dumps(t.to_chrome_trace())
    doc = json.loads(blob)
    evs = doc["traceEvents"]
    assert len(evs) == 2 and doc["displayTimeUnit"] == "ms"
    for ev in evs:
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
        assert {"name", "cat", "pid", "tid", "args"} <= set(ev)
    get = next(e for e in evs if e["name"] == "get")
    req = next(e for e in evs if e["name"] == "req")
    assert get["args"]["parent_id"] == req["args"]["span_id"]
    assert get["args"]["dollars"] == 1e-6


def test_null_tracer_is_falsy_noop():
    nt = NullTracer()
    assert not nt
    with nt.span("x", whatever=1) as sp:
        sp.set(more=2)
    sp2 = nt.begin("y")
    nt.end(sp2)
    assert nt.spans() == [] and nt.dollars() == 0.0
    assert not Tracer(enabled=False)


def test_regime_tag_crossover():
    assert regime_tag(100, 4444.4) == "fee_dominated"
    assert regime_tag(4444.4, 4444.4) == "fee_dominated"   # boundary: fee side
    assert regime_tag(10_000, 4444.4) == "egress_dominated"


# ---------------------------------------------------------------------------
# decision event log


def test_event_log_ring_bounded_totals_survive():
    log = EventLog(capacity=8)
    for i in range(20):
        log.record("miss", f"k{i}", 100, 0.5, 0.5, i, "gdsf")
    assert len(log) == 8
    assert log.dropped == 12
    assert log.counts["miss"] == 20                 # lifetime, not window
    assert log.dollars_billed("miss") == pytest.approx(10.0)
    assert log.dollars_at_stake("miss") == pytest.approx(10.0)
    assert [e.key for e in log.events("miss")] == [f"k{i}" for i in range(12, 20)]
    assert log.events("hit") == []


def test_event_log_snapshot_round_trips():
    log = EventLog(capacity=16)
    log.record("hit", "a", 10, 0.0, 2.0, 1, "lru")
    log.record("policy_swap", "", 0, 0.0, 0.0, 2, "gdsf")
    snap = json.loads(log.to_json())
    assert snap["recorded"] == 2 and snap["dropped"] == 0
    assert snap["counts"]["hit"] == 1
    assert [e["kind"] for e in snap["window"]] == ["hit", "policy_swap"]
    assert set(snap["window"][0]) == {"kind", "key", "nbytes", "dollar_delta",
                                      "dollars_at_stake", "clock", "policy"}
    assert all(k in EVENT_KINDS for k in snap["counts"])


# ---------------------------------------------------------------------------
# metrics / histograms / Prometheus

_PROM_LINE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{le="[^"]+"\})? [-+0-9.einfa]+$')


def test_histogram_buckets_and_cumulative():
    m = MetricsRegistry()
    for v in [0.5, 1.0, 3.0, 100.0]:
        m.observe_hist("h", v, bounds=[1.0, 10.0])
    h = m.hist("h")
    assert h.counts == [2, 1, 1]        # <=1, <=10, +Inf overflow
    assert h.cumulative() == [2, 3, 4]
    assert h.count == 4 and h.sum == pytest.approx(104.5)


def test_sstar_bounds_centered_on_crossover():
    sstar = 4444.444
    b = sstar_bounds(sstar, octaves=2)
    assert b == pytest.approx([sstar / 4, sstar / 2, sstar, 2 * sstar,
                               4 * sstar])
    assert log_bounds(1e-3, 1e0, per_decade=1) == pytest.approx(
        [1e-3, 1e-2, 1e-1, 1e0])


def test_prometheus_exposition_parses():
    m = MetricsRegistry()
    m.inc("egress.cache-1.hits", 3)
    m.set_gauge("governor/policy", 1.0)
    m.observe("online.window_regret", 0.25, step=10)
    m.observe_hist("egress.get_dollars", 2e-6, bounds=[1e-6, 1e-3])
    text = m.to_prometheus()
    lines = text.strip().split("\n")
    assert lines, "empty exposition"
    for ln in lines:
        assert ln.startswith("# TYPE ") or _PROM_LINE.match(ln), ln
    # histogram: cumulative buckets, +Inf == _count, names sanitized
    assert 'egress_get_dollars_bucket{le="1e-06"} 0' in lines
    assert 'egress_get_dollars_bucket{le="0.001"} 1' in lines
    assert 'egress_get_dollars_bucket{le="+Inf"} 1' in lines
    assert "egress_get_dollars_count 1" in lines
    assert "egress_cache_1_hits 3.0" in lines
    assert "online_window_regret_last 0.25" in lines


def test_metrics_registry_backcompat_reexport():
    from repro.obs.metrics import MetricsRegistry as obs_reg
    from repro.online import MetricsRegistry as online_pkg_reg
    from repro.online.metrics import MetricsRegistry as online_mod_reg
    assert obs_reg is online_pkg_reg is online_mod_reg


# ---------------------------------------------------------------------------
# egress wiring: spans + events + histograms off one live cache


def _replay(tracer=None, events=None, metrics=None):
    store = ObjectStore("s3_internet", tracer=tracer)
    for i in range(8):
        store.put(f"o{i}", bytes(1000 * (i + 1)))
    cache = EgressCache(store, capacity_bytes=6000, policy="gdsf",
                        consumer="obs_test", metrics=metrics, tracer=tracer,
                        events=events)
    rng = np.random.default_rng(0)
    for i in rng.integers(0, 8, 200):
        cache.get(f"o{i}")
    return store, cache


def test_span_dollars_equal_meter_on_egress_replay():
    tracer = Tracer()
    store, cache = _replay(tracer=tracer)
    got = tracer.dollars(name="store.get", consumer="obs_test")
    assert got == pytest.approx(cache.meter.dollars, rel=1e-12)
    assert got > 0
    # store.get spans nest under the cache.get span of the same key
    cache_by_id = {s.span_id: s for s in tracer.spans(name="cache.get")}
    store_spans = tracer.spans(name="store.get")
    assert len(store_spans) == cache.misses
    for sp in store_spans:
        parent = cache_by_id[sp.parent_id]
        assert parent.attrs["key"] == sp.attrs["key"]
        assert parent.attrs["hit"] is False
        assert sp.attrs["regime"] == regime_tag(
            sp.attrs["bytes"], store.price.crossover_bytes)


def test_event_log_miss_dollars_bit_equal_meter():
    events = EventLog()
    store, cache = _replay(events=events)
    # same-order naive accrual: not approx — bit-equal to the meter
    assert events.dollars_billed("miss") == cache.meter.dollars
    assert events.counts["hit"] == cache.hits
    assert events.counts["miss"] == cache.misses
    assert events.counts["admit"] + events.counts["reject"] == cache.misses
    assert events.counts["evict"] > 0
    cache.set_policy("lru")
    assert events.events("policy_swap")[-1].policy == "lru"
    # hits bill nothing; at-stake is what the hit saved
    assert events.dollars_billed("hit") == 0.0
    assert events.dollars_at_stake("hit") > 0


def test_size_histogram_centered_on_sstar():
    m = MetricsRegistry()
    store, cache = _replay(metrics=m)
    h = m.hist("egress.obs_test.object_bytes")
    assert h is not None
    assert h.count == cache.hits + cache.misses
    sstar = store.price.crossover_bytes
    assert any(b == pytest.approx(sstar) for b in h.bounds)
    d = m.hist("egress.obs_test.get_dollars")
    assert d.count == cache.misses
    assert d.sum == pytest.approx(cache.meter.dollars, rel=1e-9)


def test_disabled_publishers_publish_nothing():
    tracer = NullTracer()
    events = None
    store, cache = _replay(tracer=tracer, events=events)
    assert tracer.to_dicts() == []
    assert cache.meter.dollars > 0          # billing unaffected


# ---------------------------------------------------------------------------
# solver profiling hooks


def test_opt_exact_profile_counters():
    from repro.core import exact_opt_uniform, exact_opt_uniform_sweep
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 12, 300).astype(np.int32)
    costs = rng.uniform(0.5, 2.0, 12)
    r = exact_opt_uniform(ids, costs, 4)
    p = r.profile
    assert p["dijkstra_calls"] >= 1
    assert p["augmentations"] >= p["dijkstra_calls"] - 1
    assert p["paid_intervals"] > 0 and p["nodes"] > 0
    grid = np.array([1, 2, 4, 8])
    s = exact_opt_uniform_sweep(ids, costs, grid)
    sp = s.profile
    assert sp["budgets_answered"] == len(grid)
    # warm start: one parametric run answers the whole grid — far fewer
    # Dijkstra calls than solving each budget from scratch
    assert sp["dijkstra_calls"] < len(grid) * max(1, p["dijkstra_calls"])


def test_sweep_jax_profile_compile_execute_split():
    from repro.core.policies_jax import sweep_jax
    rng = np.random.default_rng(4)
    ids = rng.integers(0, 20, 200).astype(np.int32)
    cost_matrix = np.stack([rng.uniform(0.5, 2.0, 20) for _ in range(2)])
    budgets = np.array([2, 4])
    prof = {}
    out = sweep_jax("gdsf", ids, cost_matrix, budgets, num_objects=20,
                    profile=prof)
    assert prof["compile_s"] >= 0 and prof["execute_s"] >= 0
    assert prof["cells"] == out.size == 4


# ---------------------------------------------------------------------------
# schema validator + exported snapshot shape


def test_schema_validator_accepts_and_rejects():
    schema = {"type": "object", "required": ["a"],
              "properties": {"a": {"type": "integer", "minimum": 0},
                             "b": {"enum": ["x", "y"]}},
              "additionalProperties": False}
    assert validate({"a": 1, "b": "x"}, schema) == []
    errs = validate({"a": -1, "b": "z", "c": 0}, schema)
    assert len(errs) == 3
    assert validate({"b": "x"}, schema)          # missing required
    assert validate({"a": True}, schema)         # bool is not a JSON integer


def test_governance_snapshot_validates_against_checked_in_schema(tmp_path):
    import pathlib
    tracer, events, metrics = Tracer(), EventLog(), MetricsRegistry()
    store = ObjectStore("s3_internet", tracer=tracer)
    for i in range(4):
        store.put(f"o{i}", bytes(2000))
    cache = EgressCache(store, 4000, "gdsf", consumer="snap",
                        metrics=metrics, tracer=tracer, events=events)
    for i in [0, 1, 0, 2, 3, 0, 1]:
        cache.get(f"o{i}")
    snap = dict(metrics=metrics.snapshot(), store=store.meter.snapshot(),
                consumers=store.consumer_snapshot(),
                events=events.snapshot(), spans=tracer.to_dicts())
    schema = json.loads(
        (pathlib.Path(__file__).parent / "schemas" / "obs.json").read_text())
    errs = validate(json.loads(json.dumps(snap)), schema)
    assert errs == [], errs


def test_fleet_snapshot_validates_against_checked_in_schema():
    import pathlib

    from repro.fleet import Fleet
    store = ObjectStore("s3_internet")
    for i in range(8):
        store.put(f"o{i}", bytes(1500))
    fleet = Fleet(store=store, n_nodes=3, capacity_bytes=4500,
                  window_span=8.0, max_skew=2.0, gossip_every=4)
    for t in range(60):
        fleet.access(f"o{t % 8}", event_time=float(t))
    fleet.flush()
    snap = json.loads(json.dumps(fleet.snapshot()))
    schemas = pathlib.Path(__file__).parent / "schemas"
    errs = validate(snap, json.loads((schemas / "fleet.json").read_text()))
    assert errs == [], errs
    # the obs governance snapshot carries the same shape under "fleet"
    obs_schema = json.loads((schemas / "obs.json").read_text())
    errs = validate(snap, obs_schema["properties"]["fleet"])
    assert errs == [], errs


# ---------------------------------------------------------------------------
# acceptance: full governed ServeEngine run, spans sum to the meter


def test_governed_serve_span_dollars_equal_meter():
    import jax
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.serve import Request, ServeEngine

    tracer, events = Tracer(), EventLog()
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, prefix_cache_bytes=1 << 22,
                         govern=True, governor_window=4,
                         tracer=tracer, events=events)
    rng = np.random.default_rng(5)
    hot = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
           for _ in range(2)]
    rid = 0
    for _ in range(4):
        engine.serve([Request(rid + i, h, 2) for i, h in enumerate(hot)])
        rid += len(hot)
    meter = engine.cache.meter
    assert meter.dollars > 0
    span_total = tracer.dollars(name="store.get",
                                consumer=engine.cache.consumer)
    assert span_total == pytest.approx(meter.dollars, rel=1e-12)
    assert events.dollars_billed("miss") == meter.dollars
    # serve spans exist and nest: serve.request -> cache.get
    req = tracer.spans(name="serve.request")
    assert req, "no request spans recorded"
    by_id = {s.span_id: s for s in tracer.spans()}
    for s in tracer.spans(name="cache.get"):
        assert by_id[s.parent_id].name in ("serve.request", "serve.batch")
    snap = engine.governance_snapshot()
    assert "events" in snap and "spans" in snap


# ---------------------------------------------------------------------------
# NDJSON stream write-through + OTLP export


def test_tracer_stream_writes_through_ring_eviction():
    import io
    buf = io.StringIO()
    t = Tracer(max_spans=3, stream=buf)
    for i in range(10):
        with t.span(f"op{i}", cat="w", dollars=0.125 * i):
            pass
    assert t.dropped == 7                       # ring kept only the last 3
    lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
    assert [d["name"] for d in lines] == [f"op{i}" for i in range(10)]
    assert lines[4]["args"]["dollars"] == 0.5   # evicted span survived


def test_tracer_otlp_export_shape():
    t = Tracer()
    with t.span("outer", cat="test", consumer="c", dollars=0.25,
                nbytes=4096, hit=False):
        with t.span("inner", cat="test"):
            pass
    o = t.to_otlp(service_name="svc")
    res = o["resourceSpans"][0]
    assert {"key": "service.name", "value": {"stringValue": "svc"}} \
        in res["resource"]["attributes"]
    spans = res["scopeSpans"][0]["spans"]
    assert len(spans) == 2
    by_name = {s["name"]: s for s in spans}
    inner, outer = by_name["inner"], by_name["outer"]
    for s in spans:                             # OTLP id + time invariants
        assert re.fullmatch(r"[0-9a-f]{32}", s["traceId"])
        assert re.fullmatch(r"[0-9a-f]{16}", s["spanId"])
        assert int(s["endTimeUnixNano"]) >= int(s["startTimeUnixNano"]) > 0
    assert inner["parentSpanId"] == outer["spanId"]     # nesting preserved
    assert outer["parentSpanId"] == ""
    attrs = {a["key"]: a["value"] for a in outer["attributes"]}
    assert attrs["dollars"] == {"doubleValue": 0.25}
    assert attrs["nbytes"] == {"intValue": "4096"}      # i64 rides as string
    assert attrs["hit"] == {"boolValue": False}
    assert attrs["consumer"] == {"stringValue": "c"}
    json.dumps(o)                               # fully JSON-serializable
    assert NullTracer().to_otlp() == {"resourceSpans": []}


def test_tracer_write_otlp_file(tmp_path):
    t = Tracer()
    with t.span("op", cat="t"):
        pass
    p = t.write_otlp(tmp_path / "otlp.json")
    assert json.loads(p.read_text())["resourceSpans"]
