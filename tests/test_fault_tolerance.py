"""Checkpoint/restart fault tolerance: crash mid-run, resume, and land
bit-identically where an uninterrupted run lands."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore
from repro.models.registry import get_model
from repro.train.checkpoint import (latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.train.data import DataPipeline, ShardedTokenDataset
from repro.train.driver import DriverConfig, FailureInjector, TrainDriver
from repro.train.optim import OptimizerConfig, make_optimizer
from repro.train.trainer import make_train_step


def _setup(tmp_path, max_steps=12, ckpt_every=4):
    cfg = get_config("xlstm-125m", smoke=True)
    model = get_model(cfg)
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    store = ObjectStore("s3_internet")
    ds = ShardedTokenDataset(store, num_shards=4, shard_tokens=2048,
                             vocab=cfg.vocab_size).register()
    cache = EgressCache(store, capacity_bytes=4 * 2048 * 4, policy="gdsf")
    pipe = DataPipeline(ds, cache, batch_size=2, seq_len=16)
    driver = TrainDriver(
        DriverConfig(checkpoint_dir=str(tmp_path), max_steps=max_steps,
                     checkpoint_every=ckpt_every),
        step, params, opt_state, pipe)
    return driver


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.bfloat16),
            "b": [jnp.ones(5), {"c": jnp.zeros((2, 2), jnp.int32)}]}
    save_checkpoint(tmp_path, 7, tree, extra={"x": 1})
    assert latest_step(tmp_path) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got, extra = load_checkpoint(tmp_path, 7, like)
    assert extra == {"x": 1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_crash_resume_is_bit_identical(tmp_path):
    # uninterrupted reference run
    ref = _setup(tmp_path / "ref")
    ref_out = ref.run()

    # crashing run: injected failure at step 9 (after a checkpoint at 8)
    crash = _setup(tmp_path / "crash")
    crash.failure = FailureInjector(fail_at=(9,))
    with pytest.raises(RuntimeError, match="injected node failure"):
        crash.run()

    # "new process": rebuild everything, resume from disk
    resumed = _setup(tmp_path / "crash")
    assert resumed.resume()
    assert resumed.step == 8          # last complete checkpoint
    out = resumed.run()

    assert out["steps"] == ref_out["steps"]
    np.testing.assert_allclose(out["final_loss"], ref_out["final_loss"],
                               rtol=0, atol=0)
    # parameters bit-identical too
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_data_pipeline_state_resumes(tmp_path):
    store = ObjectStore("s3_internet")
    ds = ShardedTokenDataset(store, num_shards=3, shard_tokens=1024,
                             vocab=100).register()
    cache = EgressCache(store, capacity_bytes=1 << 20, policy="lru")
    p1 = DataPipeline(ds, cache, batch_size=2, seq_len=8)
    b1 = p1.next_batch()
    state = p1.state()
    b2 = p1.next_batch()
    # restore into a fresh pipeline -> identical next batch
    p2 = DataPipeline(ds, EgressCache(store, 1 << 20, "lru"), 2, 8)
    p2.restore(state)
    b2b = p2.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])


def test_straggler_detection(tmp_path):
    import time
    driver = _setup(tmp_path, max_steps=10, ckpt_every=100)
    seen = []
    driver.on_straggler = lambda s, ratio: seen.append((s, ratio))
    orig = driver.train_step

    calls = {"n": 0}

    def slow_step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 8:
            time.sleep(1.0)       # simulated slow host
        return orig(p, o, b)

    driver.train_step = slow_step
    out = driver.run()
    assert out["stragglers"], "slow step not flagged"
    assert seen and seen[0][1] > driver.cfg.straggler_factor