"""The paper's core claim: the interval LP / min-cost flow is the *exact*
dollar-optimum for uniform-size caches — validated against brute force
("to the cent ... on 250 random instances")."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (dp_opt_uniform, enumerate_opt_uniform,
                        exact_opt_uniform, lp_opt, simulate)
from repro.core.trace import Trace


def _rand_instance(rng, T, N, costs_scale="lognormal"):
    ids = rng.integers(0, N, size=T).astype(np.int32)
    if costs_scale == "lognormal":
        costs = rng.lognormal(0.0, 2.0, size=N)
    else:
        costs = rng.integers(1, 100, size=N).astype(np.float64)
    return ids, costs


# ---- the paper's brute-force validation, 250 random instances ------------

def test_flow_equals_bruteforce_250_instances():
    rng = np.random.default_rng(0)
    for trial in range(250):
        T = int(rng.integers(4, 13))
        N = int(rng.integers(2, 6))
        B = int(rng.integers(1, 4))
        ids, costs = _rand_instance(rng, T, N, "integer")
        flow = exact_opt_uniform(ids, costs, B).dollars
        dp = dp_opt_uniform(ids, costs, B)
        assert flow == pytest.approx(dp, abs=1e-6), \
            f"trial={trial} ids={ids.tolist()} B={B}"


def test_flow_equals_interval_enumeration():
    rng = np.random.default_rng(1)
    done = 0
    for trial in range(200):
        if done >= 25:
            break
        T = int(rng.integers(4, 14))
        N = int(rng.integers(2, 5))
        B = int(rng.integers(1, 4))
        ids, costs = _rand_instance(rng, T, N)
        # keep the interval count enumerable
        from repro.core import build_intervals
        ivs = build_intervals(ids, costs, np.ones(N))
        if sum(1 for iv in ivs if iv.u > iv.t + 1) > 10:
            continue
        flow = exact_opt_uniform(ids, costs, B).dollars
        enum = enumerate_opt_uniform(ids, costs, B)
        assert flow == pytest.approx(enum, rel=1e-9, abs=1e-9)
        done += 1
    assert done >= 10


def test_lp_matches_flow_uniform():
    """Total unimodularity: the LP relaxation is integral == flow optimum."""
    rng = np.random.default_rng(2)
    for _ in range(25):
        T = int(rng.integers(10, 60))
        N = int(rng.integers(3, 12))
        B = int(rng.integers(1, 6))
        ids, costs = _rand_instance(rng, T, N)
        flow = exact_opt_uniform(ids, costs, B).dollars
        lp_dollars, _, x, _ = lp_opt(ids, costs, np.ones(N), float(B))
        assert lp_dollars == pytest.approx(flow, rel=1e-6, abs=1e-6)
        # integrality of the LP vertex solution
        assert np.all((x < 1e-6) | (x > 1 - 1e-6))


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_flow_equals_dp_property(data):
    """Hypothesis: on any tiny instance, flow == state-space DP."""
    T = data.draw(st.integers(3, 11))
    N = data.draw(st.integers(1, 4))
    B = data.draw(st.integers(1, 3))
    ids = np.array(data.draw(st.lists(st.integers(0, N - 1),
                                      min_size=T, max_size=T)), np.int32)
    costs = np.array(data.draw(st.lists(
        st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False),
        min_size=N, max_size=N)))
    flow = exact_opt_uniform(ids, costs, B).dollars
    dp = dp_opt_uniform(ids, costs, B)
    assert flow == pytest.approx(dp, rel=1e-6, abs=1e-6)


def test_opt_lower_bounds_every_policy():
    rng = np.random.default_rng(3)
    for _ in range(10):
        T, N, B = 400, 40, 8
        ids, costs = _rand_instance(rng, T, N)
        tr = Trace(ids=ids, sizes=np.ones(N))
        opt = exact_opt_uniform(ids, costs, B).dollars
        for p in ("lru", "lfu", "gds", "gdsf", "belady", "cost_belady"):
            d = simulate(p, tr, costs, float(B)).dollars
            assert d >= opt - 1e-6, f"{p} beat OPT"


def test_belady_is_hit_optimal_but_not_dollar_optimal():
    """Paper §1 example: one-slot cache, cheap-hot vs expensive-cold."""
    # object 0: cheap, accessed often; object 1: expensive, accessed some
    ids = np.array([0, 1, 0, 1, 0, 1, 0, 1, 0, 1], np.int32)
    costs = np.array([1e-5, 1.0])
    B = 1
    opt = exact_opt_uniform(ids, costs, B)
    # with B=1 and alternating requests, nobody can save across gaps
    assert opt.savings == pytest.approx(0.0)
    ids2 = np.array([0, 0, 1, 0, 0, 1, 0, 0, 1], np.int32)
    opt2 = exact_opt_uniform(ids2, costs, 1)
    # exact OPT keeps only the three free adjacent repeats of object 0
    assert opt2.savings == pytest.approx(3 * 1e-5)
    # with B=2 every gap fits: all 5 object-0 reuses + both object-1 gaps
    opt3 = exact_opt_uniform(ids2, costs, 2)
    assert opt3.savings == pytest.approx(5 * 1e-5 + 2 * 1.0)


def test_flow_scales():
    """Scale-stability machinery: exact flow at 1e4 requests runs fast."""
    rng = np.random.default_rng(4)
    T, N, B = 10_000, 400, 64
    ids = rng.integers(0, N, size=T).astype(np.int32)
    costs = rng.lognormal(0, 2, size=N)
    r = exact_opt_uniform(ids, costs, B)
    assert 0 < r.dollars < r.total_no_cache
    # spot-check against the sparse LP
    lp_dollars, _, _, _ = lp_opt(ids, costs, np.ones(N), float(B))
    assert lp_dollars == pytest.approx(r.dollars, rel=1e-6)


def test_selected_schedule_is_feasible():
    rng = np.random.default_rng(5)
    T, N, B = 600, 50, 6
    ids = rng.integers(0, N, size=T).astype(np.int32)
    costs = rng.lognormal(0, 1.5, size=N)
    r = exact_opt_uniform(ids, costs, B, return_selected=True)
    occ = np.zeros(T, np.int64)
    for iv in r.selected:
        occ[iv.t + 1:iv.u] += 1
    assert occ.max() <= B - 1
