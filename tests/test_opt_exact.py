"""The paper's core claim: the interval LP / min-cost flow is the *exact*
dollar-optimum for uniform-size caches — validated against brute force
("to the cent ... on 250 random instances").

Property-based (hypothesis) variants live in test_opt_exact_property.py so
this module collects even where hypothesis is not installed.
"""
import time

import numpy as np
import pytest

from repro.core import (dp_opt_uniform, enumerate_opt_uniform,
                        exact_opt_uniform, exact_opt_uniform_sweep, lp_opt,
                        simulate)
from repro.core.trace import Trace


def _rand_instance(rng, T, N, costs_scale="lognormal"):
    ids = rng.integers(0, N, size=T).astype(np.int32)
    if costs_scale == "lognormal":
        costs = rng.lognormal(0.0, 2.0, size=N)
    else:
        costs = rng.integers(1, 100, size=N).astype(np.float64)
    return ids, costs


# ---- the paper's brute-force validation, 250 random instances ------------

def test_flow_equals_bruteforce_250_instances():
    rng = np.random.default_rng(0)
    for trial in range(250):
        T = int(rng.integers(4, 13))
        N = int(rng.integers(2, 6))
        B = int(rng.integers(1, 4))
        ids, costs = _rand_instance(rng, T, N, "integer")
        flow = exact_opt_uniform(ids, costs, B).dollars
        dp = dp_opt_uniform(ids, costs, B)
        assert flow == pytest.approx(dp, abs=1e-6), \
            f"trial={trial} ids={ids.tolist()} B={B}"


def test_flow_equals_interval_enumeration():
    rng = np.random.default_rng(1)
    done = 0
    for trial in range(200):
        if done >= 25:
            break
        T = int(rng.integers(4, 14))
        N = int(rng.integers(2, 5))
        B = int(rng.integers(1, 4))
        ids, costs = _rand_instance(rng, T, N)
        # keep the interval count enumerable
        from repro.core import build_intervals
        ivs = build_intervals(ids, costs, np.ones(N))
        if sum(1 for iv in ivs if iv.u > iv.t + 1) > 10:
            continue
        flow = exact_opt_uniform(ids, costs, B).dollars
        enum = enumerate_opt_uniform(ids, costs, B)
        assert flow == pytest.approx(enum, rel=1e-9, abs=1e-9)
        done += 1
    assert done >= 10


def test_lp_matches_flow_uniform():
    """Total unimodularity: the LP relaxation is integral == flow optimum."""
    rng = np.random.default_rng(2)
    for _ in range(25):
        T = int(rng.integers(10, 60))
        N = int(rng.integers(3, 12))
        B = int(rng.integers(1, 6))
        ids, costs = _rand_instance(rng, T, N)
        flow = exact_opt_uniform(ids, costs, B).dollars
        lp_dollars, _, x, _ = lp_opt(ids, costs, np.ones(N), float(B))
        assert lp_dollars == pytest.approx(flow, rel=1e-6, abs=1e-6)
        # integrality of the LP vertex solution
        assert np.all((x < 1e-6) | (x > 1 - 1e-6))


def test_opt_lower_bounds_every_policy():
    rng = np.random.default_rng(3)
    for _ in range(10):
        T, N, B = 400, 40, 8
        ids, costs = _rand_instance(rng, T, N)
        tr = Trace(ids=ids, sizes=np.ones(N))
        opt = exact_opt_uniform(ids, costs, B).dollars
        for p in ("lru", "lfu", "gds", "gdsf", "belady", "cost_belady"):
            d = simulate(p, tr, costs, float(B)).dollars
            assert d >= opt - 1e-6, f"{p} beat OPT"


def test_belady_is_hit_optimal_but_not_dollar_optimal():
    """Paper §1 example: one-slot cache, cheap-hot vs expensive-cold."""
    # object 0: cheap, accessed often; object 1: expensive, accessed some
    ids = np.array([0, 1, 0, 1, 0, 1, 0, 1, 0, 1], np.int32)
    costs = np.array([1e-5, 1.0])
    B = 1
    opt = exact_opt_uniform(ids, costs, B)
    # with B=1 and alternating requests, nobody can save across gaps
    assert opt.savings == pytest.approx(0.0)
    ids2 = np.array([0, 0, 1, 0, 0, 1, 0, 0, 1], np.int32)
    opt2 = exact_opt_uniform(ids2, costs, 1)
    # exact OPT keeps only the three free adjacent repeats of object 0
    assert opt2.savings == pytest.approx(3 * 1e-5)
    # with B=2 every gap fits: all 5 object-0 reuses + both object-1 gaps
    opt3 = exact_opt_uniform(ids2, costs, 2)
    assert opt3.savings == pytest.approx(5 * 1e-5 + 2 * 1.0)


def test_flow_scales():
    """Scale-stability machinery: exact flow at 1e4 requests runs fast."""
    rng = np.random.default_rng(4)
    T, N, B = 10_000, 400, 64
    ids = rng.integers(0, N, size=T).astype(np.int32)
    costs = rng.lognormal(0, 2, size=N)
    r = exact_opt_uniform(ids, costs, B)
    assert 0 < r.dollars < r.total_no_cache
    # spot-check against the sparse LP
    lp_dollars, _, _, _ = lp_opt(ids, costs, np.ones(N), float(B))
    assert lp_dollars == pytest.approx(r.dollars, rel=1e-6)


# ---- parametric budget sweep ---------------------------------------------

def test_sweep_equals_per_budget_random_traces():
    """One warm-started SSP run == K independent solves, dollar for dollar."""
    rng = np.random.default_rng(11)
    for trial in range(6):
        T = int(rng.integers(200, 1500))
        N = int(rng.integers(10, 120))
        ids = rng.integers(0, N, T).astype(np.int32)
        costs = rng.lognormal(0, 2, N)
        budgets = np.unique(rng.integers(1, max(3, N), size=6)).astype(np.int64)
        sweep = exact_opt_uniform_sweep(ids, costs, budgets)
        for B, d, h in zip(budgets, sweep.dollars, sweep.hits):
            ref = exact_opt_uniform(ids, costs, int(B))
            assert d == pytest.approx(ref.dollars, rel=1e-6, abs=1e-9), \
                f"trial={trial} B={B}"
            assert int(h) == ref.hits, f"trial={trial} B={B}"


def test_sweep_unit_path_costs_monotone():
    """SSP augments along non-decreasing path costs — the property that
    makes every budget a prefix of the same run."""
    rng = np.random.default_rng(12)
    ids = rng.integers(0, 50, 2000).astype(np.int32)
    costs = rng.lognormal(0, 2, 50)
    sweep = exact_opt_uniform_sweep(ids, costs, np.array([40]))
    pc = sweep.unit_path_costs
    assert (pc < 0).all()
    assert (np.diff(pc) >= -1e-9 * np.abs(pc[:-1])).all()
    # dollars are non-increasing and savings non-decreasing in budget
    full = exact_opt_uniform_sweep(ids, costs, np.arange(1, 41))
    assert (np.diff(full.dollars) <= 1e-9).all()
    assert (np.diff(full.hits) >= 0).all()


def test_sweep_edge_cases():
    rng = np.random.default_rng(13)
    ids = rng.integers(0, 8, 60).astype(np.int32)
    costs = rng.lognormal(0, 1, 8)
    # budget 1 keeps only free (adjacent-repeat) gaps; budget 0 keeps nothing
    sweep = exact_opt_uniform_sweep(ids, costs, np.array([0, 1, 1000]))
    r0 = exact_opt_uniform(ids, costs, 0)
    r1 = exact_opt_uniform(ids, costs, 1)
    rbig = exact_opt_uniform(ids, costs, 1000)
    assert sweep.dollars[0] == pytest.approx(r0.dollars)
    assert sweep.dollars[1] == pytest.approx(r1.dollars)
    # beyond saturation the optimum flattens at keep-everything
    assert sweep.dollars[2] == pytest.approx(rbig.dollars, rel=1e-9)
    assert sweep.total_no_cache == pytest.approx(r1.total_no_cache)
    with pytest.raises(ValueError):
        exact_opt_uniform_sweep(ids, costs, np.zeros((0,), np.int64))


def test_sweep_is_faster_than_independent_solves():
    """The headline perf property at a CI-friendly scale: the sweep costs
    about one largest solve, not sum-of-solves (full 100k-scale >=5x bound
    is asserted in benchmarks/bench_flow_scale.py)."""
    rng = np.random.default_rng(14)
    T, N = 20_000, 800
    ids = rng.integers(0, N, T).astype(np.int32)
    costs = rng.lognormal(0, 2, N)
    budgets = np.linspace(4, 48, 8).astype(np.int64)
    t0 = time.perf_counter()
    sweep = exact_opt_uniform_sweep(ids, costs, budgets)
    dt_sweep = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref = [exact_opt_uniform(ids, costs, int(B)).dollars for B in budgets]
    dt_ind = time.perf_counter() - t0
    for d, r in zip(sweep.dollars, ref):
        assert d == pytest.approx(r, rel=1e-6)
    # ~4x asymptotically at this grid; demand 2x to stay timing-robust
    assert dt_ind > 2.0 * dt_sweep, \
        f"sweep {dt_sweep:.2f}s vs independent {dt_ind:.2f}s"


def test_selected_schedule_is_feasible():
    rng = np.random.default_rng(5)
    T, N, B = 600, 50, 6
    ids = rng.integers(0, N, size=T).astype(np.int32)
    costs = rng.lognormal(0, 1.5, size=N)
    r = exact_opt_uniform(ids, costs, B, return_selected=True)
    occ = np.zeros(T, np.int64)
    for iv in r.selected:
        occ[iv.t + 1:iv.u] += 1
    assert occ.max() <= B - 1
