"""Hypothesis property tests for cost-FOO's segment-tree rounding.

The fast `round_fractional` (lazy range-add/range-min headroom tree,
DESIGN.md §4) must be *bit-identical* to `round_fractional_reference`
(the pre-optimization quadratic oracle): same greedy ordering keys, same
float expression shapes, same stable sort — so the accepted set, the
saved-dollar accumulation order, and hence the final float agree exactly.
Sizes are drawn integer-valued so all occupancy arithmetic is exact and
the relative tolerance can never flip a comparison between the two paths.

Guarded with `pytest.importorskip`: hypothesis is optional in the
container; the fixed-seed parity checks in test_cost_foo.py cover the
same claim where it is absent.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Trace, build_interval_arrays,  # noqa: E402
                        interval_deltas, round_fractional,
                        round_fractional_reference, zcap_profile)
from repro.core.cost_foo import _round_arrays, _round_tol  # noqa: E402
from repro.core.opt_exact import lp_opt  # noqa: E402


def _draw_instance(data):
    T = data.draw(st.integers(4, 60))
    N = data.draw(st.integers(2, 8))
    ids = np.array(data.draw(st.lists(st.integers(0, N - 1),
                                      min_size=T, max_size=T)), np.int32)
    # integer sizes keep occupancy arithmetic exact (see module docstring)
    sizes = np.array(data.draw(st.lists(st.integers(1, 9),
                                        min_size=N, max_size=N)), np.float64)
    B = float(data.draw(st.integers(1, 30)))
    return ids, sizes, B


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_segment_tree_rounding_bit_identical(data):
    """Hypothesis: fast rounding == quadratic reference, bit for bit."""
    ids, sizes, B = _draw_instance(data)
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    # varied miss costs make the density tiebreak order nontrivial
    costs = rng.lognormal(0.0, 1.0, len(sizes))
    t, u, obj, save, size = build_interval_arrays(ids, costs, sizes)
    if len(t) == 0:
        return
    # arbitrary fractional x in [0, 1] — rounding must agree on ANY x,
    # not just LP solutions
    x = rng.random(len(t))
    from repro.core.opt_exact import Interval
    paid_iv = [Interval(int(tt), int(uu), int(oo), float(sv), float(sz))
               for tt, uu, oo, sv, sz in zip(t, u, obj, save, size)]
    fast = round_fractional(ids, sizes, B, x, paid_iv)
    ref = round_fractional_reference(ids, sizes, B, x, paid_iv)
    assert fast == ref  # exact float equality, not approx


@settings(max_examples=80, deadline=None)
@given(st.data())
def test_rounded_schedule_never_exceeds_zcap(data):
    """Hypothesis: the accepted set's occupancy respects zcap everywhere."""
    ids, sizes, B = _draw_instance(data)
    seed = data.draw(st.integers(0, 2**31 - 1))
    t, u, obj, save, size = build_interval_arrays(
        ids, np.ones_like(sizes), sizes)
    if len(t) == 0:
        return
    rng = np.random.default_rng(seed)
    x = rng.random(len(t))
    T = len(ids)
    zcap = zcap_profile(ids, sizes, B)
    tol = _round_tol(B)
    _, accepted = _round_arrays(t, u, save, size, x, zcap, tol)
    if not accepted.any():
        return
    deltas = interval_deltas(t[accepted], u[accepted], size[accepted], T)
    occ = np.cumsum(deltas)
    assert (occ[1:] <= zcap[1:] + tol).all(), (
        float((occ[1:] - zcap[1:]).max()), tol)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_rounding_lp_solution_bounded_by_lp(data):
    """Hypothesis: rounding the LP's own x never beats the LP bound."""
    ids, sizes, B = _draw_instance(data)
    costs = np.ones_like(sizes)
    _, lp_savings, x, paid = lp_opt(ids, costs, sizes, B)
    if not paid:
        return
    saved = round_fractional(ids, sizes, B, x, paid)
    assert saved <= lp_savings + 1e-9 * max(1.0, lp_savings)
