"""Serve billing path: one GET per unique prefix, hot-swap invariants,
governed engine wiring."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serve import Request, ServeEngine


def _engine(**kw):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return ServeEngine(model, params, prefix_cache_bytes=1 << 22, **kw), cfg


def test_one_get_per_unique_prefix():
    """Repeated identical prefixes bill exactly one GET each: the first
    re-serve fetches the stored prefix KV (billed), every later one hits
    the local cache (never re-billed)."""
    engine, cfg = _engine()
    rng = np.random.default_rng(0)
    a = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    b = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    for _ in range(5):
        engine.serve([Request(0, a, 2)])
    for _ in range(3):
        engine.serve([Request(1, b, 2)])
    assert engine.store.meter.gets == 2            # one per unique prefix
    assert engine.cache.meter.gets == 2            # ... attributed to the cache
    assert engine.cache.hits == (4 - 1) + (2 - 1)  # every later touch is a hit
    # serve the hot prefix once more: still no new billing
    engine.serve([Request(2, a, 2)])
    assert engine.store.meter.gets == 2


def test_hot_swap_mid_stream_preserves_contents_and_billing():
    engine, cfg = _engine(policy="gdsf")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(3)]
    for p in prompts:
        engine.serve([Request(0, p, 2)])
        engine.serve([Request(1, p, 2)])           # warm: 1 GET per prefix
    gets_before = engine.store.meter.gets
    resident = set(engine.cache._data)
    engine.cache.set_policy("lru")                 # hot-swap mid-stream
    assert set(engine.cache._data) == resident     # contents preserved
    out = [engine.serve([Request(2, p, 2)])[0].output for p in prompts]
    assert engine.store.meter.gets == gets_before  # swap never re-bills
    assert all(o is not None for o in out)
    assert engine.cache.policy == "lru"


def test_governed_engine_serves_and_snapshots():
    engine, cfg = _engine(govern=True, governor_window=4)
    rng = np.random.default_rng(2)
    hot = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    for i in range(6):
        engine.serve([Request(i, hot, 2)])
    assert engine.governor is not None
    snap = engine.governance_snapshot()
    assert "governor" in snap and "metrics" in snap
    assert snap["consumers"].keys() == {"serve_prefix_cache"}
    assert snap["store"]["dollars"] == pytest.approx(
        snap["consumers"]["serve_prefix_cache"]["dollars"])
    # the engine published through the registry
    assert engine.metrics.counter("serve.requests") == 6
    assert engine.metrics.counter("egress.serve_prefix_cache.hits") > 0
    # windowed audit over the prefix traffic works end to end
    rep = engine.governor.audit()
    assert rep is not None and rep.dollar_regret >= 0
