"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Property-based (hypothesis) variants live in test_kernels_property.py so this
module collects even where hypothesis is not installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core.trace import next_use_indices


@pytest.mark.parametrize("T,N,block_t", [
    (64, 8, 16), (100, 5, 32), (1000, 37, 256), (4096, 513, 1024),
    (777, 13, 128), (1, 1, 8), (2048, 2048, 512),
])
def test_next_use_shapes(T, N, block_t):
    rng = np.random.default_rng(T * 31 + N)
    ids = rng.integers(0, N, T).astype(np.int32)
    got = np.asarray(ops.next_use(jnp.asarray(ids), N, block_t=block_t))
    want = next_use_indices(ids, N)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("N,block_n,dtype", [
    (128, 64, jnp.float32), (1000, 256, jnp.float32),
    (8192, 2048, jnp.float32), (555, 128, jnp.bfloat16),
    (2048, 512, jnp.bfloat16),
])
def test_evict_argmin_shapes(N, block_n, dtype):
    rng = np.random.default_rng(N)
    scores = rng.standard_normal(N).astype(np.float32)
    touch = rng.integers(0, 10_000, N).astype(np.int32)
    mask = rng.random(N) < 0.5
    if not mask.any():
        mask[0] = True
    s = jnp.asarray(scores).astype(dtype)
    gi, gv = ops.evict_argmin(s, jnp.asarray(touch), jnp.asarray(mask),
                              block_n=block_n)
    wi, wv = ref.evict_argmin_ref(s, jnp.asarray(touch), jnp.asarray(mask))
    assert int(gi) == int(wi)
    np.testing.assert_allclose(np.float32(gv), np.float32(wv), rtol=1e-6)


def test_evict_argmin_lexicographic_ties():
    scores = jnp.zeros(512, jnp.float32)  # all tied
    touch = jnp.arange(512, 0, -1, dtype=jnp.int32)  # last entry oldest
    mask = jnp.ones(512, bool)
    gi, _ = ops.evict_argmin(scores, touch, mask, block_n=128)
    assert int(gi) == 511  # smallest touch wins


def test_evict_argmin_empty_mask():
    scores = jnp.zeros(128, jnp.float32)
    touch = jnp.zeros(128, jnp.int32)
    mask = jnp.zeros(128, bool)
    _, gv = ops.evict_argmin(scores, touch, mask, block_n=64)
    assert float(gv) > 1e37  # +BIG sentinel


@pytest.mark.parametrize("T,block_t,dtype", [
    (100, 32, jnp.float32), (4096, 1024, jnp.float32),
    (777, 256, jnp.float32), (2000, 512, jnp.int32),
])
def test_interval_occupancy_shapes(T, block_t, dtype):
    rng = np.random.default_rng(T)
    deltas = rng.integers(-3, 4, T).astype(np.float32)
    got = np.asarray(ops.interval_occupancy(
        jnp.asarray(deltas).astype(dtype), block_t=block_t))
    want = np.cumsum(deltas.astype(np.float32))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("T,block_t,dtype", [
    (100, 32, jnp.float32), (4096, 1024, jnp.float32),
    (777, 256, jnp.float32), (2000, 512, jnp.int32), (1, 8, jnp.float32),
    (2049, 2048, jnp.float32),
])
def test_occupancy_feasible_shapes(T, block_t, dtype):
    rng = np.random.default_rng(T * 7 + 1)
    deltas = rng.integers(-3, 4, T).astype(np.float32)
    zcap = rng.integers(0, 8, T).astype(np.float32)
    got_occ, got_ex = ops.occupancy_feasible(
        jnp.asarray(deltas).astype(dtype), jnp.asarray(zcap),
        block_t=block_t)
    want_occ, want_ex = ref.occupancy_feasible_ref(
        jnp.asarray(deltas).astype(dtype), jnp.asarray(zcap))
    np.testing.assert_allclose(np.asarray(got_occ), np.asarray(want_occ),
                               rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(float(got_ex), float(want_ex),
                               rtol=1e-6, atol=1e-5)


def test_occupancy_feasible_sign():
    """excess <= 0 iff the schedule fits under zcap at every instant."""
    deltas = jnp.asarray(np.array([2.0, 1.0, -1.0, 3.0], np.float32))
    zcap_ok = jnp.asarray(np.array([5.0, 5.0, 5.0, 5.0], np.float32))
    zcap_bad = jnp.asarray(np.array([5.0, 5.0, 5.0, 4.0], np.float32))
    _, ex_ok = ops.occupancy_feasible(deltas, zcap_ok, block_t=2)
    _, ex_bad = ops.occupancy_feasible(deltas, zcap_bad, block_t=2)
    assert float(ex_ok) <= 0.0       # occ = [2,3,2,5] fits under 5
    assert float(ex_bad) == 1.0      # final instant: 5 vs cap 4


def test_occupancy_of_opt_schedule_respects_budget():
    """End-to-end: the exact optimum's schedule through the kernel is
    feasible at every serving instant."""
    from repro.core import exact_opt_uniform
    rng = np.random.default_rng(7)
    T, N, B = 2000, 100, 12
    ids = rng.integers(0, N, T).astype(np.int32)
    costs = rng.lognormal(0, 2, N)
    r = exact_opt_uniform(ids, costs, B, return_selected=True)
    deltas = np.zeros(T, np.float32)
    for iv in r.selected:
        deltas[iv.t + 1] += 1
        if iv.u < T:
            deltas[iv.u] -= 1
    occ = np.asarray(ops.interval_occupancy(jnp.asarray(deltas)))
    assert occ.max() <= B - 1 + 1e-6