"""Per-arch smoke tests: reduced config, one forward/train/decode step on CPU,
shape + finiteness asserts (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model


def _batch(model, B=2, S=16, seed=0):
    cfg = model.cfg
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_vision_tokens, cfg.d_model)),
            cfg.param_dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), cfg.param_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(model)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    loss = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # a one-hot-ish sanity: loss should be near log(V) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_step(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(model)
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    flat, _ = jax.tree.flatten(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: bad grads"
    # at least one nonzero gradient leaf
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(2))
    B, S = 2, 16
    batch = _batch(model, B=B, S=S)
    pre_batch = dict(batch)
    pre_batch.pop("labels")
    logits, caches = model.prefill(params, pre_batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # grow caches to decode length if the family uses preallocated KV
    caches = _grow_caches(model, caches, B, S + 4)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(2):
        logits, caches = model.decode_step(params, tok, caches,
                                           jnp.int32(S + step))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: step {step}"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def _grow_caches(model, caches, B, max_len):
    """Pad prefill KV caches with empty slots up to max_len (transformer and
    whisper families preallocate; recurrent families carry O(1) state;
    window-capped local caches shift in place and are left alone)."""
    cfg = model.cfg
    if cfg.family in ("dense", "moe", "vlm"):
        out = []
        for l, (k, v) in enumerate(caches):
            if cfg.window and k.shape[1] <= cfg.window and (
                    cfg.global_every <= 0 or not cfg.is_global_layer(l)):
                out.append((k, v))  # shift cache: fixed W slots
                continue
            pad = max_len - k.shape[1]
            out.append((jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))))
        return out
    if cfg.family == "encdec":
        out = []
        for (sk, sv, ck, cv) in caches:
            pad = max_len - sk.shape[1]
            out.append((jnp.pad(sk, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        jnp.pad(sv, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        ck, cv))
        return out
    return caches


def test_decode_matches_forward_xlstm():
    """Chunkwise-parallel training form == recurrent decode form (xLSTM)."""
    cfg = get_config("xlstm-125m", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(3))
    B, S = 1, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    # parallel logits for every prefix position
    logits_par = model.forward(params, {"tokens": toks})
    # sequential decode
    from repro.models import xlstm as xm
    states = xm.init_state(cfg, B, cfg.param_dtype)
    outs = []
    for t in range(S):
        lg, states = model.decode_step(params, toks[:, t], states, jnp.int32(t))
        outs.append(lg)
    logits_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=0.05, atol=0.05)


def test_decode_matches_forward_rglru():
    """Associative-scan training form == stepwise decode (RG-LRU hybrid)."""
    cfg = get_config("recurrentgemma-9b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(4))
    B, S = 1, 9
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_par = model.forward(params, {"tokens": toks})
    from repro.models import rglru as rg
    caches = rg.init_caches(cfg, B, 32, cfg.param_dtype)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, toks[:, t], caches, jnp.int32(t))
        outs.append(lg)
    logits_seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_seq, np.float32),
                               rtol=0.05, atol=0.05)


def test_gemma3_window_pattern():
    cfg = get_config("gemma3-4b")
    globals_ = [l for l in range(cfg.num_layers) if cfg.is_global_layer(l)]
    assert globals_ == [5, 11, 17, 23, 29]  # every 6th layer (5:1)


def test_rglru_pattern():
    cfg = get_config("recurrentgemma-9b")
    attn = [l for l in range(9) if cfg.is_attn_layer(l)]
    assert attn == [2, 5, 8]  # (rec, rec, attn) repeating


def test_kimi_first_layer_dense():
    cfg = get_config("kimi-k2-1t-a32b")
    assert not cfg.is_moe_layer(0)
    assert cfg.is_moe_layer(1) and cfg.is_moe_layer(60)