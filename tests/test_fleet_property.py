"""Property tests for the fleet wire format + gossip convergence.

Guarded with `pytest.importorskip`: hypothesis is optional in the
container, and collection must not die where it is absent (the fixed-seed
cases in test_fleet.py cover the same contracts either way).

Contracts under test:
  * serialize -> deserialize round-trips every field, with dollars
    (miss_cost / per-policy totals) bit-equal — `float.hex()` identity,
    not approx;
  * any single-byte corruption of a frame raises `WireError` (CRC-32
    detects all burst errors <= 32 bits, so one flipped byte can never
    half-parse), as does a version bump or a kind mismatch;
  * anti-entropy gossip converges under drop+duplicate+reorder+delay for
    every seed — merge idempotence/commutativity means faults change the
    path, never the fixpoint.
"""
import math

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.egress.cache import ONLINE_POLICIES, AccessEvent  # noqa: E402
from repro.fleet import (GossipState, SimNetwork, WindowDelta,  # noqa: E402
                         WireError, access_event_from_json,
                         access_event_to_json, decode_access_event,
                         decode_window_delta, encode_access_event,
                         encode_window_delta)

finite_f64 = st.floats(allow_nan=False, allow_infinity=False, width=64)
keys = st.text(min_size=1, max_size=40)

events = st.builds(
    AccessEvent,
    key=keys,
    nbytes=st.integers(0, 2**48),
    hit=st.booleans(),
    miss_cost=finite_f64,
    policy=st.sampled_from(ONLINE_POLICIES),
    clock=st.integers(0, 2**48),
    event_time=finite_f64,
)

deltas = st.builds(
    WindowDelta,
    host=keys,
    window_id=st.integers(0, 2**32),
    seq=st.integers(0, 2**32),
    watermark=finite_f64,
    events=st.integers(0, 2**31),
    dollars=st.dictionaries(st.sampled_from(ONLINE_POLICIES), finite_f64,
                            max_size=len(ONLINE_POLICIES)),
)


@settings(max_examples=100, deadline=None)
@given(events)
def test_access_event_binary_round_trip(ev):
    back = decode_access_event(encode_access_event(ev))
    assert back == ev
    assert back.miss_cost.hex() == ev.miss_cost.hex()       # bit-equal
    assert back.event_time.hex() == ev.event_time.hex()


@settings(max_examples=100, deadline=None)
@given(events)
def test_access_event_json_round_trip(ev):
    back = access_event_from_json(access_event_to_json(ev))
    assert back == ev
    assert back.miss_cost.hex() == ev.miss_cost.hex()


@settings(max_examples=100, deadline=None)
@given(deltas)
def test_window_delta_round_trip(d):
    back = decode_window_delta(encode_window_delta(d))
    assert back == d
    for p, v in d.dollars.items():
        assert back.dollars[p].hex() == v.hex()


@settings(max_examples=100, deadline=None)
@given(events, st.data())
def test_single_byte_corruption_always_rejected(ev, data):
    frame = bytearray(encode_access_event(ev))
    pos = data.draw(st.integers(0, len(frame) - 1))
    mask = data.draw(st.integers(1, 255))
    frame[pos] ^= mask
    with pytest.raises(WireError):
        decode_access_event(bytes(frame))


@settings(max_examples=50, deadline=None)
@given(events, st.integers(1, 254))
def test_version_bump_rejected_even_with_valid_crc(ev, bump):
    import binascii
    import struct
    frame = bytearray(encode_access_event(ev))
    frame[2] = (frame[2] + bump) % 256
    frame[-4:] = struct.pack("<I", binascii.crc32(bytes(frame[:-4])))
    with pytest.raises(WireError):
        decode_access_event(bytes(frame))


@settings(max_examples=50, deadline=None)
@given(deltas)
def test_kind_mismatch_rejected(d):
    with pytest.raises(WireError):
        decode_access_event(encode_window_delta(d))


# ---------------------------------------------------------------------------
# gossip convergence under faults, deterministic per seed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(10))
def test_gossip_converges_under_faults_deterministic(seed):
    """Anti-entropy over a faulty switch reaches the unique fixpoint: the
    union of everyone's deltas, identical dollars at every participant."""
    hosts = [f"h{i}" for i in range(4)]
    states = {h: GossipState() for h in hosts}
    for i, h in enumerate(hosts):
        for w in range(3):
            states[h].merge(WindowDelta(h, w, w + 1, float(w), 1,
                                        {"lru": 0.25 * (i + 1) + w}))
    net = SimNetwork(seed, drop=0.3, duplicate=0.3, reorder=0.5, max_delay=2)
    rounds = 0
    while len({s.digest() for s in states.values()}) > 1:
        rounds += 1
        assert rounds <= 50, "gossip failed to converge"
        for h in hosts:
            frames = [encode_window_delta(d)
                      for d in states[h].deltas.values()]
            for peer in hosts:
                if peer != h:
                    for f in frames:
                        net.send(h, peer, f)
        for dst, _src, frame in net.deliver():
            states[dst].merge(decode_window_delta(frame))
    totals = [s.fleet_totals() for s in states.values()]
    assert all(t == totals[0] for t in totals)
    assert len(states[hosts[0]].deltas) == len(hosts) * 3
    expect = math.fsum(0.25 * (i + 1) + w
                       for i in range(4) for w in range(3))
    assert totals[0]["lru"] == expect
