"""Sharding rules: divisibility-aware logical->mesh mapping (unit level,
no devices needed beyond CPU)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh


class FakeMesh:
    """Duck-typed stand-in so rule logic is testable without 512 devices."""
    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)
        self.size = int(np.prod(list(shape_dict.values())))


def _rules(multi=True, moe_ep=False):
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi
                    else {"data": 16, "model": 16})
    return sh.ShardingRules(
        mesh,
        {"embed": tuple(a for a in ("pod", "data") if a in mesh.axis_names),
         "vocab": "model", "heads": "model", "kv_heads": "model",
         "mlp": "model",
         "expert": "model" if moe_ep else None},
        dp_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names))


def test_fsdp_tp_spec():
    r = _rules()
    spec = r.spec_for((7168, 2048), ("embed", "mlp"))
    assert spec == P(("pod", "data"), "model")


def test_divisibility_fallback_drops_leading_axis():
    r = _rules()
    # 16 rows on a 32-way ("pod","data") axis -> keep the 16-way "data"
    spec = r.spec_for((16, 128), ("embed", None))
    assert spec == P("data", None)


def test_indivisible_drops_to_none():
    r = _rules()
    # a bare 20-head axis on a 16-way model axis: replicate
    spec = r.spec_for((1280, 20), ("embed", "kv_heads"))
    assert spec[1] is None
    # but the packed G*hd projection dim (20*64=1280) is divisible and shards
    spec2 = r.spec_for((1280, 20 * 64), ("embed", "kv_heads"))
    assert spec2[1] == "model"


def test_axis_used_once():
    r = _rules(multi=False)
    # two dims both wanting "model": second gets None
    spec = r.spec_for((2048, 2048), ("heads", "mlp"))
    assert spec == P("model", None)


def test_moe_ep_rules():
    r = _rules(moe_ep=True)
    spec = r.spec_for((384, 7168, 2048), ("expert", "embed", "mlp"))
    assert spec[0] == "model"       # experts over TP axis
    assert spec[2] is None          # mlp can't reuse "model"


def test_odd_dims_never_crash():
    r = _rules()
    for dims in [(1,), (3, 5), (17, 33, 7)]:
        spec = r.spec_for(dims, tuple(["embed", "heads", "mlp"][:len(dims)]))
        assert len(spec) == len(dims)