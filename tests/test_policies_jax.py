"""JAX lax.scan policy simulator == Python reference, step for step."""
import numpy as np
import pytest

from repro.core import Trace, simulate
from repro.core.policies_jax import POLICY_WEIGHTS, simulate_jax, sweep_jax


def _rand(rng, T, N):
    ids = rng.integers(0, N, T).astype(np.int32)
    # power-of-two costs: every score the policies form is exact in f32,
    # so the JAX sim must match the f64 Python reference bit-for-bit
    costs = 2.0 ** rng.integers(0, 12, N)
    return ids, costs


@pytest.mark.parametrize("policy", ["lru", "lfu", "gds", "gdsf",
                                    "belady", "cost_belady"])
def test_jax_matches_python_uniform(policy):
    rng = np.random.default_rng(hash(policy) % 2**32)
    for trial in range(8):
        T = int(rng.integers(50, 300))
        N = int(rng.integers(5, 40))
        B = int(rng.integers(1, max(2, N // 2)))
        ids, costs = _rand(rng, T, N)
        tr = Trace(ids=ids, sizes=np.ones(N))
        ref = simulate(policy, tr, costs, float(B))
        d, h = simulate_jax(policy, ids, costs, B, num_objects=N)
        assert h == ref.hits, f"{policy} trial={trial} hits {h} != {ref.hits}"
        assert d == pytest.approx(ref.dollars, rel=1e-5), f"{policy} t={trial}"


def test_sweep_shape_and_consistency():
    rng = np.random.default_rng(0)
    ids, costs = _rand(rng, 200, 20)
    cost_matrix = np.stack([costs, 10 * costs, costs ** 2])
    budgets = np.array([2, 4, 8])
    out = sweep_jax("gdsf", ids, cost_matrix, budgets, num_objects=20)
    assert out.shape == (3, 3)
    # more budget never costs more dollars (same price vector)
    assert (np.diff(out, axis=1) <= 1e-4).all()
    # single-cell agreement
    d, _ = simulate_jax("gdsf", ids, cost_matrix[1], 4, num_objects=20)
    assert out[1, 1] == pytest.approx(d, rel=1e-6)


def test_all_policies_registered():
    assert set(POLICY_WEIGHTS) == {"lru", "lfu", "gds", "gdsf",
                                   "belady", "cost_belady"}
