"""JAX lax.scan policy simulator == Python reference, step for step."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Trace, simulate
from repro.core.policies_jax import (POLICY_WEIGHTS, _simulate, simulate_jax,
                                     stack_policy_weights, sweep_jax)
from repro.core.trace import next_use_indices


def _rand(rng, T, N):
    ids = rng.integers(0, N, T).astype(np.int32)
    # power-of-two costs: every score the policies form is exact in f32,
    # so the JAX sim must match the f64 Python reference bit-for-bit
    costs = 2.0 ** rng.integers(0, 12, N)
    return ids, costs


@pytest.mark.parametrize("policy", ["lru", "lfu", "gds", "gdsf",
                                    "belady", "cost_belady"])
def test_jax_matches_python_uniform(policy):
    rng = np.random.default_rng(hash(policy) % 2**32)
    for trial in range(8):
        T = int(rng.integers(50, 300))
        N = int(rng.integers(5, 40))
        B = int(rng.integers(1, max(2, N // 2)))
        ids, costs = _rand(rng, T, N)
        tr = Trace(ids=ids, sizes=np.ones(N))
        ref = simulate(policy, tr, costs, float(B))
        d, h = simulate_jax(policy, ids, costs, B, num_objects=N)
        assert h == ref.hits, f"{policy} trial={trial} hits {h} != {ref.hits}"
        assert d == pytest.approx(ref.dollars, rel=1e-5), f"{policy} t={trial}"


def test_sweep_shape_and_consistency():
    rng = np.random.default_rng(0)
    ids, costs = _rand(rng, 200, 20)
    cost_matrix = np.stack([costs, 10 * costs, costs ** 2])
    budgets = np.array([2, 4, 8])
    out = sweep_jax("gdsf", ids, cost_matrix, budgets, num_objects=20)
    assert out.shape == (3, 3)
    # more budget never costs more dollars (same price vector)
    assert (np.diff(out, axis=1) <= 1e-4).all()
    # single-cell agreement
    d, _ = simulate_jax("gdsf", ids, cost_matrix[1], 4, num_objects=20)
    assert out[1, 1] == pytest.approx(d, rel=1e-6)


def test_all_policies_registered():
    assert set(POLICY_WEIGHTS) == {"lru", "lfu", "gds", "gdsf",
                                   "belady", "cost_belady"}


def test_multi_policy_sweep_matches_per_cell():
    """The (policies x prices x budgets) grid — one compiled program —
    reproduces every per-cell simulate_jax result exactly."""
    rng = np.random.default_rng(7)
    ids, costs = _rand(rng, 250, 24)
    cost_matrix = np.stack([costs, 8 * costs, costs / 4, 64 * costs])
    budgets = np.array([2, 4, 8, 12])
    policies = list(POLICY_WEIGHTS)
    out = sweep_jax(policies, ids, cost_matrix, budgets, num_objects=24)
    assert out.shape == (6, 4, 4)
    for q, pol in enumerate(policies):
        for p in range(4):
            for k, B in enumerate(budgets):
                d, _ = simulate_jax(pol, ids, cost_matrix[p], int(B),
                                    num_objects=24)
                assert out[q, p, k] == np.float32(d), \
                    f"cell ({pol}, price {p}, B={B})"


def test_multi_policy_sweep_accepts_weight_stack():
    rng = np.random.default_rng(8)
    ids, costs = _rand(rng, 120, 10)
    stack = stack_policy_weights(["lru", "belady"])
    out = sweep_jax(stack, ids, costs[None, :], np.array([3]), num_objects=10)
    assert out.shape == (2, 1, 1)
    ref = sweep_jax(["lru", "belady"], ids, costs[None, :], np.array([3]),
                    num_objects=10)
    np.testing.assert_array_equal(out, ref)
    with pytest.raises(ValueError):
        sweep_jax(np.zeros((2, 5), np.float32), ids, costs[None, :],
                  np.array([3]), num_objects=10)


@pytest.mark.parametrize("policy", ["lru", "gdsf", "cost_belady"])
def test_pallas_victim_path_matches_jnp_step_for_step(policy):
    """`_simulate` with the Pallas evict_argmin kernel (interpret mode on
    CPU) must track the jnp victim path through the WHOLE trajectory, not
    just the final totals."""
    rng = np.random.default_rng(hash(policy) % 2**32)
    T, N, B = 150, 16, 5
    ids, costs = _rand(rng, T, N)
    nxt = next_use_indices(ids).astype(np.int32)
    args = (jnp.asarray(ids), jnp.asarray(nxt),
            jnp.asarray(costs, jnp.float32), jnp.ones(N, jnp.float32),
            jnp.int32(B), jnp.asarray(POLICY_WEIGHTS[policy].as_array()), N)
    d_j, h_j, (dol_j, hit_j) = _simulate(*args, use_pallas=False,
                                         trace_steps=True)
    d_p, h_p, (dol_p, hit_p) = _simulate(*args, use_pallas=True,
                                         trace_steps=True)
    np.testing.assert_array_equal(np.asarray(hit_j), np.asarray(hit_p))
    np.testing.assert_array_equal(np.asarray(dol_j), np.asarray(dol_p))
    assert float(d_j) == float(d_p) and int(h_j) == int(h_p)


def test_pallas_victim_path_full_api():
    """End-to-end through simulate_jax/sweep_jax with use_pallas=True."""
    rng = np.random.default_rng(9)
    ids, costs = _rand(rng, 100, 12)
    for policy in ("lfu", "gds", "belady"):
        d1, h1 = simulate_jax(policy, ids, costs, 4, num_objects=12,
                              use_pallas=False)
        d2, h2 = simulate_jax(policy, ids, costs, 4, num_objects=12,
                              use_pallas=True)
        assert (d1, h1) == (d2, h2), policy
    out_j = sweep_jax(["lru", "gdsf"], ids, costs[None, :], np.array([3, 6]),
                      num_objects=12, use_pallas=False)
    out_p = sweep_jax(["lru", "gdsf"], ids, costs[None, :], np.array([3, 6]),
                      num_objects=12, use_pallas=True)
    np.testing.assert_array_equal(out_j, out_p)
