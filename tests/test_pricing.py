"""Price vectors, miss costs (eq. 1), crossover s* (eq. 3), heterogeneity H."""
import numpy as np
import pytest

from repro.core import PRICE_VECTORS, crossover_bytes, heterogeneity, miss_costs


def test_crossover_matches_paper():
    """Paper §3: s* ~ 4.4 KB S3-internet, ~20 KB S3 cross-region,
    ~460 B Azure, ~330 B GCS."""
    assert crossover_bytes(PRICE_VECTORS["s3_internet"]) == pytest.approx(4444, rel=0.05)
    assert crossover_bytes(PRICE_VECTORS["s3_cross_region"]) == pytest.approx(20000, rel=0.05)
    assert crossover_bytes(PRICE_VECTORS["azure_internet"]) == pytest.approx(460, rel=0.05)
    assert crossover_bytes(PRICE_VECTORS["gcs_internet"]) == pytest.approx(333, rel=0.05)


def test_miss_cost_linear_in_size():
    pv = PRICE_VECTORS["s3_internet"]
    sizes = np.array([0.0, 1e3, 1e6, 1e9])
    c = miss_costs(sizes, pv)
    assert c[0] == pytest.approx(pv.get_fee)
    assert c[3] == pytest.approx(pv.get_fee + 0.09, rel=1e-9)
    # below s*: GET-fee dominated; above: egress dominated
    sstar = pv.crossover_bytes
    assert pv.miss_cost(sstar / 100) < 1.02 * pv.get_fee
    assert pv.miss_cost(sstar * 100) > 50 * pv.get_fee


def test_paper_intro_example():
    """1 KB x100 accesses vs 1 GB x10: dollar gap > 4 orders of magnitude."""
    pv = PRICE_VECTORS["s3_internet"]
    small_saving = 100 * pv.miss_cost(1e3)   # ~ $5e-5
    big_saving = 10 * pv.miss_cost(1e9)      # ~ $0.9
    assert small_saving == pytest.approx(5e-5, rel=0.5)
    assert big_saving == pytest.approx(0.9, rel=0.1)
    assert big_saving / small_saving > 1e4


def test_heterogeneity_zero_for_homogeneous():
    ids = np.array([0, 1, 2, 0, 1])
    costs = np.full(3, 2.5)
    assert heterogeneity(ids, costs) == pytest.approx(0.0)


def test_heterogeneity_rises_with_dispersion():
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 50, 1000)
    base = np.ones(50)
    h_low = heterogeneity(ids, base * (1 + 0.01 * rng.standard_normal(50)))
    h_high = heterogeneity(ids, np.exp(2 * rng.standard_normal(50)))
    assert h_low < 0.05 < h_high
