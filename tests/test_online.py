"""Online dollar-governance subsystem: shadow panel, windowed audit,
s*-aware admission, governor hot-swap, per-consumer billing attribution."""
import numpy as np
import pytest

from repro.core.pricing import PRICE_VECTORS, PriceVector
from repro.egress import EgressCache, ObjectStore
from repro.online import (DollarGovernor, MetricsRegistry, SStarAdmission,
                          ShadowCache, ShadowPanel, WindowedAuditor)
from repro.online.scenario import (EGRESS_HEAVY, FEE_HEAVY,
                                   regime_shift_scenario, run_fixed,
                                   run_governed)

ONLINE = ("lru", "lfu", "gds", "gdsf")


def _uniform_store(price="s3_internet", n=32, size=4096):
    store = ObjectStore(price)
    for i in range(n):
        store.put(f"o{i}", bytes(size))
    return store


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry_roundtrip(tmp_path):
    m = MetricsRegistry()
    m.inc("a")
    m.inc("a", 2)
    m.set_gauge("g", 1.5)
    m.observe("s", 0.1, step=10)
    m.observe("s", 0.2, step=20)
    assert m.counter("a") == 3
    assert m.latest("s") == pytest.approx(0.2)
    snap = m.snapshot()
    assert snap["gauges"]["g"] == 1.5
    assert snap["series"]["s"] == [[10, 0.1], [20, 0.2]]
    p = m.write_json(tmp_path / "metrics.json")
    import json
    assert json.loads(p.read_text())["counters"]["a"] == 3


# ---------------------------------------------------------------------------
# per-consumer billing attribution (audit satellite)
# ---------------------------------------------------------------------------

def test_audit_excludes_other_consumers():
    store = _uniform_store()
    cache = EgressCache(store, 8 * 4096, "lru", consumer="mine")
    for i in range(16):
        cache.get(f"o{i}")
    # another consumer hammers the store directly: must NOT pollute audit
    for _ in range(50):
        store.get("o0", consumer="other")
    rep = cache.audit()
    assert rep.observed_dollars == pytest.approx(cache.meter.dollars)
    assert store.meter.dollars > rep.observed_dollars
    assert store.meter_for("other").gets == 50


def test_consumer_dollars_sum_to_store_total():
    store = _uniform_store()
    a = EgressCache(store, 4 * 4096, "lru", consumer="a")
    b = EgressCache(store, 4 * 4096, "gdsf", consumer="b")
    rng = np.random.default_rng(0)
    for i in rng.integers(0, 32, 300):
        (a if i % 2 else b).get(f"o{i}")
    per = store.consumer_snapshot()
    assert set(per) == {"a", "b"}
    assert sum(m["dollars"] for m in per.values()) == \
        pytest.approx(store.meter.dollars)


def test_audit_budget_grid_one_sweep():
    store = _uniform_store()
    cache = EgressCache(store, 4 * 4096, "lru")
    rng = np.random.default_rng(1)
    for i in rng.zipf(1.2, 400) % 32:
        cache.get(f"o{i}")
    rep = cache.audit(budget_grid=[1, 2, 8, 16])
    assert rep.opt_by_budget is not None
    assert set(rep.opt_by_budget) >= {1, 2, 8, 16}
    # exact OPT-dollars are non-increasing in budget
    ds = [rep.opt_by_budget[b] for b in sorted(rep.opt_by_budget)]
    assert all(x >= y - 1e-12 for x, y in zip(ds, ds[1:]))
    # the bracket refers to the cache's own budget (4 pages), also in the grid
    assert rep.opt_dollars_lower == pytest.approx(rep.opt_by_budget[4])


def test_repricing_accrues_not_rewrites():
    store = ObjectStore("s3_internet")
    store.put("k", bytes(1000))
    store.get("k")
    d1 = store.meter.dollars
    pv = PRICE_VECTORS["s3_internet"]
    assert d1 == pytest.approx(float(pv.miss_cost(1000)))
    store.set_price("gcs_internet")
    store.get("k")
    pv2 = PRICE_VECTORS["gcs_internet"]
    assert store.meter.dollars == pytest.approx(
        d1 + float(pv2.miss_cost(1000)))


# ---------------------------------------------------------------------------
# shadow panel
# ---------------------------------------------------------------------------

def test_shadow_panel_bills_zero_egress():
    store = _uniform_store()
    cache = EgressCache(store, 8 * 4096, "lru", consumer="live")
    panel = ShadowPanel(cache.capacity, ONLINE)
    cache.add_listener(panel.on_event)
    rng = np.random.default_rng(2)
    for i in rng.integers(0, 32, 500):
        cache.get(f"o{i}")
    # every billed dollar is attributed to the live cache; shadows are free
    assert set(store.consumer_snapshot()) == {"live"}
    assert store.meter.dollars == pytest.approx(cache.meter.dollars)
    # yet the panel DID account counterfactual dollars
    assert all(d > 0 for d in panel.dollars().values())


def test_shadow_matches_live_policy_exactly():
    """A shadow running the live cache's own policy must reproduce its bill
    step-for-step: same priorities, same tiebreaks, same dollars."""
    for policy in ONLINE:
        store = _uniform_store(n=24, size=2048)
        cache = EgressCache(store, 5 * 2048, policy, consumer=f"live_{policy}")
        shadow = ShadowCache(policy, cache.capacity)
        cache.add_listener(
            lambda ev, sh=shadow: sh.access(ev.key, ev.nbytes, ev.miss_cost))
        rng = np.random.default_rng(3)
        for i in rng.zipf(1.3, 600) % 24:
            cache.get(f"o{i}")
        assert shadow.hits == cache.hits, policy
        assert shadow.misses == cache.misses, policy
        assert shadow.dollars == pytest.approx(cache.meter.dollars), policy


# ---------------------------------------------------------------------------
# windowed audit
# ---------------------------------------------------------------------------

def test_window_ring_buffer_caps_length():
    store = _uniform_store()
    cache = EgressCache(store, 8 * 4096, "lru")
    aud = WindowedAuditor(cache.capacity, window=64)
    cache.add_listener(aud.on_event)
    for i in range(200):
        cache.get(f"o{i % 32}")
    assert len(aud) == 64


def test_window_audit_uniform_exact_sweep():
    store = _uniform_store()
    cache = EgressCache(store, 4 * 4096, "lru")
    m = MetricsRegistry()
    aud = WindowedAuditor(cache.capacity, window=256,
                          budget_grid=[2, 4, 8], metrics=m)
    cache.add_listener(aud.on_event)
    rng = np.random.default_rng(4)
    for i in rng.zipf(1.2, 400) % 32:
        cache.get(f"o{i}")
    rep = aud.audit()
    assert rep.uniform
    assert rep.opt_dollars_lower == rep.opt_dollars_upper  # exact, not bracket
    assert rep.observed_dollars >= rep.opt_dollars_lower - 1e-12
    assert rep.dollar_regret >= 0
    ds = [rep.opt_by_budget[b] for b in sorted(rep.opt_by_budget)]
    assert all(x >= y - 1e-12 for x, y in zip(ds, ds[1:]))
    assert m.latest("online.window_regret") == pytest.approx(rep.dollar_regret)


def test_window_audit_variable_sizes_bracket():
    store = ObjectStore("gcs_internet")
    rng = np.random.default_rng(5)
    sizes = rng.integers(500, 50_000, 16)
    for i, s in enumerate(sizes):
        store.put(f"o{i}", bytes(int(s)))
    cache = EgressCache(store, 60_000, "gdsf")
    aud = WindowedAuditor(cache.capacity, window=256)
    cache.add_listener(aud.on_event)
    for i in rng.integers(0, 16, 250):
        cache.get(f"o{i}")
    rep = aud.audit()
    assert not rep.uniform
    assert rep.opt_dollars_lower <= rep.opt_dollars_upper + 1e-12
    assert rep.observed_dollars >= rep.opt_dollars_lower - 1e-12


def test_empty_window_audit_is_none():
    aud = WindowedAuditor(1000, window=16)
    assert aud.audit() is None


def test_window_audit_tolerates_out_of_order_event_times():
    """Events delivered out of event-time order (within the skew bound)
    audit identically to the same events delivered sorted: the buffer
    insorts by event time through the shared Watermark helper."""
    from repro.egress.cache import AccessEvent
    rng = np.random.default_rng(11)
    evs = [AccessEvent(f"o{i % 7}", 4096, bool(i % 3), 0.001 * (i % 5 + 1),
                       "lru", i, float(i)) for i in range(120)]
    # bounded shuffle: displace each event by < max_skew positions
    skewed = list(evs)
    for i in range(0, len(skewed) - 4, 4):
        seg = skewed[i:i + 4]
        rng.shuffle(seg)
        skewed[i:i + 4] = seg
    ordered, jumbled = (WindowedAuditor(8 * 4096, window=64, max_skew=8.0)
                        for _ in range(2))
    for ev in evs:
        ordered.on_event(ev)
    for ev in skewed:
        jumbled.on_event(ev)
    assert jumbled.watermark.late > 0          # the shuffle did something
    a, b = ordered.audit(), jumbled.audit()
    assert (a.observed_dollars, a.opt_dollars_lower, a.requests) == \
        (b.observed_dollars, b.opt_dollars_lower, b.requests)
    # beyond the bound the clock model is broken, not merely late
    strict = WindowedAuditor(8 * 4096, window=64, max_skew=2.0)
    strict.on_event(evs[50])
    with pytest.raises(ValueError):
        strict.on_event(evs[10])


# ---------------------------------------------------------------------------
# s*-aware admission
# ---------------------------------------------------------------------------

def test_sstar_admission_rules():
    pv = PriceVector("t", get_fee=1e-6, egress_per_byte=1e-9)  # s* = 1000 B
    adm = SStarAdmission(pv, capacity_bytes=100_000,
                         large_object_frac=0.5)
    assert adm.admit("a", 500, 1)          # below s*: always keep
    assert not adm.admit("b", 60_000, 5)   # > 50% of capacity: never
    assert not adm.admit("c", 5_000, 1)    # egress-dominated, first touch
    assert adm.admit("c", 5_000, 2)        # ... admitted on reuse
    assert adm.admitted == 2 and adm.bypassed == 2


def test_admission_plugged_into_cache_bypasses():
    store = ObjectStore(PriceVector("t", get_fee=1e-6, egress_per_byte=1e-9))
    store.put("small", bytes(500))
    store.put("mid", bytes(5_000))
    adm = SStarAdmission(store, capacity_bytes=100_000)
    cache = EgressCache(store, 100_000, "lru", admission=adm)
    cache.get("small")
    assert cache.get("small")  # resident: admitted below s*
    assert store.meter.gets == 1
    cache.get("mid")           # first touch: bypassed (fetch-through)
    assert cache.bypasses == 1
    cache.get("mid")           # second touch: missed again, now admitted
    assert store.meter.gets == 3
    cache.get("mid")
    assert store.meter.gets == 3  # resident now


def test_admission_tracks_price_flip():
    store = ObjectStore(FEE_HEAVY)          # s* = 10 MB: everything admitted
    store.put("obj", bytes(50_000))
    adm = SStarAdmission(store, capacity_bytes=10_000_000)
    assert adm.admit("obj", 50_000, 1)
    store.set_price(EGRESS_HEAVY)           # s* = 10 B: now on probation
    assert not adm.admit("obj2", 50_000, 1)


# ---------------------------------------------------------------------------
# governor + regime shift (acceptance)
# ---------------------------------------------------------------------------

def test_policy_hot_swap_preserves_contents_and_bill():
    store = _uniform_store()
    cache = EgressCache(store, 8 * 4096, "lru")
    for i in range(8):
        cache.get(f"o{i}")
    resident = dict(cache._data)
    bill = cache.meter.dollars
    cache.set_policy("gdsf")
    assert cache._data == resident
    assert cache.used == sum(len(v) for v in resident.values())
    assert cache.meter.dollars == bill          # the swap itself bills $0
    for i in range(8):
        cache.get(f"o{i}")                      # all hits: still unbilled
    assert cache.meter.dollars == bill
    assert cache.policy_swaps == 1


def test_governor_swaps_toward_cheaper_shadow():
    """LFU start on a drifting working set: the governor must leave LFU."""
    store = ObjectStore(FEE_HEAVY)
    for i in range(200):
        store.put(f"o{i}", bytes(1024))
    cache = EgressCache(store, 20 * 1024, "lfu", consumer="live")
    gov = DollarGovernor(cache, window=100, hysteresis=0.05)
    rng = np.random.default_rng(6)
    base = 0
    for step in range(1200):
        if step and step % 150 == 0:
            base += 10                      # working set drifts: LFU stales
        cache.get(f"o{base + int(rng.integers(12))}")
    assert cache.policy != "lfu"
    assert len(gov.swaps) >= 1
    assert gov.swaps[0].old_policy == "lfu"


def test_regime_shift_governor_within_10pct_of_best_fixed():
    """The ISSUE's acceptance criterion: price vector flipped across s*
    mid-trace; governed realized dollars within 10% of the best fixed
    policy in hindsight; shadow panel bills $0 of extra egress."""
    sc = regime_shift_scenario(n_phase=3000, seed=0)
    fixed = {p: run_fixed(sc, p)["dollars"] for p in ONLINE}
    best_policy = min(fixed, key=lambda p: fixed[p])
    m = MetricsRegistry()
    gov_res, gov = run_governed(sc, metrics=m)
    assert gov_res["dollars"] <= 1.10 * fixed[best_policy], \
        (gov_res, fixed)
    # the governor actually adapted (regime shift = at least one swap)
    assert len(gov_res["swaps"]) >= 1
    # shadow panel billed $0 extra egress: every store dollar is attributed
    # to the governed cache's own consumer meter, and to nothing else
    store_dollars = gov.cache.store.meter.dollars
    per_consumer = gov.cache.store.consumer_snapshot()
    assert set(per_consumer) == {"governed"}
    assert per_consumer["governed"]["dollars"] == pytest.approx(store_dollars)
    # metrics saw the swaps and the per-policy window series
    assert m.counter("governor.swaps") == len(gov_res["swaps"])
    assert any(k.startswith("governor.window_dollars.") for k in m.series)


def test_regime_shift_phase_winners_flip():
    """The scenario really is a regime shift: the per-phase winner changes
    across the price flip (recency wins fee-dominated, cost-awareness wins
    egress-dominated)."""
    sc = regime_shift_scenario(n_phase=3000, seed=0)
    phase = {}
    for p in ("lru", "gdsf"):
        store = sc.make_store()
        cache = EgressCache(store, sc.capacity_bytes, p, consumer="x")
        ph1 = None
        for t, key in enumerate(sc.keys):
            if t == sc.flip_at:
                store.set_price(sc.price_b)
                ph1 = cache.meter.dollars
            cache.get(key)
        phase[p] = (ph1, cache.meter.dollars - ph1)
    assert phase["lru"][0] < phase["gdsf"][0]    # fee phase: LRU cheaper
    assert phase["gdsf"][1] < phase["lru"][1]    # egress phase: GDSF cheaper
