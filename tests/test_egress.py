"""Egress cache + billing-faithful store + offline audit integration."""
import numpy as np
import pytest

from repro.core import PRICE_VECTORS
from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore


def _store_with_objects(price="gcs_internet", n=20, size=1000):
    store = ObjectStore(price)
    for i in range(n):
        store.put(f"obj{i}", bytes(size))
    store.meter.puts = 0
    store.meter.gets = 0
    store.meter.bytes_egressed = 0.0
    return store


def test_billing_eq1():
    store = ObjectStore("s3_internet")
    store.put("a", bytes(1000))
    store.get("a")
    pv = PRICE_VECTORS["s3_internet"]
    assert store.meter.dollars == pytest.approx(pv.get_fee + 1000 * pv.egress_per_byte)
    store.get("a")
    assert store.meter.gets == 2


def test_cache_hits_avoid_billing():
    store = _store_with_objects()
    cache = EgressCache(store, capacity_bytes=10_000, policy="lru")
    for _ in range(5):
        cache.get("obj0")
    assert store.meter.gets == 1      # one billed miss, four local hits
    assert cache.hit_rate == pytest.approx(4 / 5)


def test_eviction_respects_budget():
    store = _store_with_objects(n=10, size=1000)
    cache = EgressCache(store, capacity_bytes=3000, policy="lru")
    for i in range(10):
        cache.get(f"obj{i}")
    assert cache.used <= 3000


def test_gdsf_keeps_expensive_objects():
    store = ObjectStore("gcs_internet")
    store.put("cheap", bytes(100))
    store.put("costly", bytes(10_000_000))   # egress-dominated
    cache = EgressCache(store, capacity_bytes=10_000_100, policy="gdsf")
    pattern = (["costly"] + ["cheap"] * 3) * 10
    for k in pattern:
        cache.get(k)
    # the expensive object should rarely be refetched
    assert store.meter.dollars < 5 * PRICE_VECTORS["gcs_internet"].miss_cost(10_000_000)


def test_audit_reports_regret_vs_exact_opt():
    store = _store_with_objects(n=8, size=4096)
    cache = EgressCache(store, capacity_bytes=3 * 4096, policy="lru")
    rng = np.random.default_rng(0)
    for i in rng.integers(0, 8, 500):
        cache.get(f"obj{i}")
    rep = cache.audit()
    assert rep.requests == 500
    assert rep.observed_dollars >= rep.opt_dollars_lower - 1e-12
    assert rep.dollar_regret >= 0
    assert 0 <= rep.hit_rate <= 1
    assert "regret" in rep.summary()


def test_lazy_objects_not_materialized():
    store = ObjectStore("s3_internet")
    store.register_lazy("big", 12345, lambda: bytes(12345))
    assert store.size_of("big") == 12345
    data = store.get("big")
    assert len(data) == 12345
    assert store.meter.bytes_egressed == 12345