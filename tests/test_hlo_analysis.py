"""HLO collective-bytes parser: shapes, tuples, while-trip multiplication."""
from repro.launch.hlo_analysis import (_shape_bytes, _split_computations,
                                       analyze_collectives)


def test_shape_bytes():
    assert _shape_bytes("f32[2,512,1024]") == 2 * 512 * 1024 * 4
    assert _shape_bytes("bf16[16]{0}") == 32
    assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


_HLO = """
HloModule test

%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

%cond (p2: (s32[], f32[128])) -> pred[] {
  %p2 = (s32[], f32[128]) parameter(0)
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[256]) -> f32[256] {
  %a = f32[256]{0} parameter(0)
  %ag = f32[256]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[256]{0} add(%ag, %ag)
}
"""


def test_while_trip_multiplication():
    cs = analyze_collectives(_HLO)
    # all-gather once at entry: 256*4 bytes
    assert cs.bytes_by_kind["all-gather"] == 256 * 4
    # all-reduce inside the while body: 128*4 bytes * 7 trips
    assert cs.bytes_by_kind["all-reduce"] == 128 * 4 * 7
    assert cs.count_by_kind["all-reduce"] == 7


def test_split_handles_tuple_params():
    comps = _split_computations(_HLO)
    assert "body" in comps and "cond" in comps and "main" in comps


def test_instruction_name_with_opcode_substring():
    hlo = """
ENTRY %main (a: f32[4]) -> f32[4] {
  %all-gather.61 = f32[4]{0} all-gather(%a), dimensions={0}
  ROOT %r = f32[4]{0} add(%all-gather.61, %all-gather.61)
}
"""
    cs = analyze_collectives(hlo)
    assert cs.count_by_kind["all-gather"] == 1
    assert cs.bytes_by_kind["all-gather"] == 16