"""Hypothesis property tests for the exact solvers.

Guarded with `pytest.importorskip`: hypothesis is optional in the container,
and collection must not die where it is absent (the 250-instance fixed-seed
brute-force sweep in test_opt_exact.py covers the same claim either way).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dp_opt_uniform, exact_opt_uniform  # noqa: E402
from repro.core.opt_exact import exact_opt_uniform_sweep  # noqa: E402


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_flow_equals_dp_property(data):
    """Hypothesis: on any tiny instance, flow == state-space DP."""
    T = data.draw(st.integers(3, 11))
    N = data.draw(st.integers(1, 4))
    B = data.draw(st.integers(1, 3))
    ids = np.array(data.draw(st.lists(st.integers(0, N - 1),
                                      min_size=T, max_size=T)), np.int32)
    costs = np.array(data.draw(st.lists(
        st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False),
        min_size=N, max_size=N)))
    flow = exact_opt_uniform(ids, costs, B).dollars
    dp = dp_opt_uniform(ids, costs, B)
    assert flow == pytest.approx(dp, rel=1e-6, abs=1e-6)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_sweep_equals_per_budget_property(data):
    """Hypothesis: the parametric sweep == independent per-budget solves."""
    T = data.draw(st.integers(3, 40))
    N = data.draw(st.integers(1, 8))
    ids = np.array(data.draw(st.lists(st.integers(0, N - 1),
                                      min_size=T, max_size=T)), np.int32)
    costs = np.array(data.draw(st.lists(
        st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False),
        min_size=N, max_size=N)))
    budgets = np.array(sorted(data.draw(st.sets(st.integers(1, 10),
                                                min_size=1, max_size=5))))
    sweep = exact_opt_uniform_sweep(ids, costs, budgets)
    for B, d in zip(budgets, sweep.dollars):
        ref = exact_opt_uniform(ids, costs, int(B)).dollars
        assert d == pytest.approx(ref, rel=1e-9, abs=1e-9)
