"""cost-FOO bracket for variable-size caching (paper §2, §4)."""
import numpy as np
import pytest

from repro.core import (PRICE_VECTORS, Trace, cost_foo, exact_opt_uniform,
                        lp_opt, miss_costs, round_fractional,
                        round_fractional_reference, zipf_trace)


def test_lower_bound_below_feasible_upper():
    tr = zipf_trace(n_objects=80, n_requests=1200, mean_size=32 * 1024, seed=2)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
    B = float(np.sort(tr.sizes)[-20:].sum())  # room for ~20 large objects
    r = cost_foo(tr, costs, B)
    assert r.lower <= r.upper + 1e-9
    assert r.lower > 0
    assert r.bracket >= 0


def test_bracket_is_tight_on_synthetic():
    """Paper: median bracket ~0.04 on variable-size synthetic traces."""
    brackets = []
    for seed in range(6):
        tr = zipf_trace(n_objects=100, n_requests=1500, sigma=1.5,
                        mean_size=64 * 1024, seed=seed)
        costs = miss_costs(tr.sizes, PRICE_VECTORS["s3_internet"])
        B = float(np.quantile(tr.sizes, 0.8) * 25)
        brackets.append(cost_foo(tr, costs, B).bracket)
    med = float(np.median(brackets))
    assert med < 0.15, f"median bracket {med} too loose: {brackets}"


def test_lp_reduces_to_exact_for_uniform():
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 30, 500).astype(np.int32)
    costs = rng.lognormal(0, 2, 30)
    tr = Trace(ids=ids, sizes=np.ones(30))
    r = cost_foo(tr, costs, 8.0, policies=("gdsf", "belady", "cost_belady"))
    exact = exact_opt_uniform(ids, costs, 8).dollars
    assert r.lower == pytest.approx(exact, rel=1e-6)


def test_fractional_lower_bound_below_uniform_opt():
    """LP with sizes==1 must equal the flow optimum (integrality)."""
    rng = np.random.default_rng(4)
    ids = rng.integers(0, 25, 400).astype(np.int32)
    costs = rng.lognormal(0, 1.5, 25)
    lo, _, x, _ = lp_opt(ids, costs, np.ones(25), 6.0)
    exact = exact_opt_uniform(ids, costs, 6).dollars
    assert lo == pytest.approx(exact, rel=1e-6)


def test_segment_tree_rounding_matches_reference_fixed_seeds():
    """Fast rounding == quadratic oracle on real lognormal-size traces."""
    for seed in range(4):
        tr = zipf_trace(n_objects=60, n_requests=900, sigma=1.4,
                        mean_size=48 * 1024, seed=seed)
        costs = miss_costs(tr.sizes, PRICE_VECTORS["s3_internet"])
        B = float(np.quantile(tr.sizes, 0.8) * 18)
        _, _, x, paid = lp_opt(tr.ids, costs, tr.sizes, B)
        fast = round_fractional(tr.ids, tr.sizes, B, x, paid)
        ref = round_fractional_reference(tr.ids, tr.sizes, B, x, paid)
        assert fast == ref  # bit-identical, not approx


def test_epoch_decomposition_brackets_monolithic():
    """Forced small epochs must keep a valid bracket: the decomposed lower
    bound never exceeds the monolithic LP's (it is a relaxation of it) and
    the rounded upper stays feasible-above-lower."""
    tr = zipf_trace(n_objects=120, n_requests=6000, sigma=1.2,
                    mean_size=32 * 1024, seed=11)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
    B = float(np.quantile(tr.sizes, 0.8) * 30)
    mono = cost_foo(tr, costs, B, policies=("gdsf",))
    dec = cost_foo(tr, costs, B, policies=("gdsf",), epoch_len=1500,
                   epoch_overlap=0.5)
    assert mono.profile["epochs"] == 1
    assert dec.profile["epochs"] > 1
    assert dec.lower <= mono.lower + 1e-9 * max(1.0, mono.lower)
    assert dec.lower <= dec.upper + 1e-9
    # still a usable bound: decomposition gives up a bounded amount here
    assert dec.lower >= 0.5 * mono.lower


def test_epoch_len_covering_trace_is_monolithic():
    """epoch_len >= T must reproduce the monolithic bracket exactly —
    same code path, bit-for-bit."""
    tr = zipf_trace(n_objects=50, n_requests=1200, mean_size=16 * 1024,
                    seed=7)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["s3_internet"])
    B = float(np.quantile(tr.sizes, 0.8) * 15)
    auto = cost_foo(tr, costs, B, policies=("gdsf",))
    forced = cost_foo(tr, costs, B, policies=("gdsf",),
                      epoch_len=len(tr.ids) + 100)
    assert forced.lower == auto.lower
    assert forced.upper == auto.upper


def test_validate_kernel_checks_rounded_schedule():
    """validate=True replays the accepted schedule through the Pallas
    occupancy_feasible kernel; any infeasibility would assert inside."""
    tr = zipf_trace(n_objects=40, n_requests=800, mean_size=24 * 1024,
                    seed=5)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["s3_internet"])
    B = float(np.quantile(tr.sizes, 0.8) * 12)
    r = cost_foo(tr, costs, B, policies=("gdsf",), validate=True)
    assert r.lower <= r.upper + 1e-9
    assert r.profile["rounded_intervals"] >= 0
