"""cost-FOO bracket for variable-size caching (paper §2, §4)."""
import numpy as np
import pytest

from repro.core import (PRICE_VECTORS, Trace, cost_foo, exact_opt_uniform,
                        lp_opt, miss_costs, zipf_trace)


def test_lower_bound_below_feasible_upper():
    tr = zipf_trace(n_objects=80, n_requests=1200, mean_size=32 * 1024, seed=2)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
    B = float(np.sort(tr.sizes)[-20:].sum())  # room for ~20 large objects
    r = cost_foo(tr, costs, B)
    assert r.lower <= r.upper + 1e-9
    assert r.lower > 0
    assert r.bracket >= 0


def test_bracket_is_tight_on_synthetic():
    """Paper: median bracket ~0.04 on variable-size synthetic traces."""
    brackets = []
    for seed in range(6):
        tr = zipf_trace(n_objects=100, n_requests=1500, sigma=1.5,
                        mean_size=64 * 1024, seed=seed)
        costs = miss_costs(tr.sizes, PRICE_VECTORS["s3_internet"])
        B = float(np.quantile(tr.sizes, 0.8) * 25)
        brackets.append(cost_foo(tr, costs, B).bracket)
    med = float(np.median(brackets))
    assert med < 0.15, f"median bracket {med} too loose: {brackets}"


def test_lp_reduces_to_exact_for_uniform():
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 30, 500).astype(np.int32)
    costs = rng.lognormal(0, 2, 30)
    tr = Trace(ids=ids, sizes=np.ones(30))
    r = cost_foo(tr, costs, 8.0, policies=("gdsf", "belady", "cost_belady"))
    exact = exact_opt_uniform(ids, costs, 8).dollars
    assert r.lower == pytest.approx(exact, rel=1e-6)


def test_fractional_lower_bound_below_uniform_opt():
    """LP with sizes==1 must equal the flow optimum (integrality)."""
    rng = np.random.default_rng(4)
    ids = rng.integers(0, 25, 400).astype(np.int32)
    costs = rng.lognormal(0, 1.5, 25)
    lo, _, x, _ = lp_opt(ids, costs, np.ones(25), 6.0)
    exact = exact_opt_uniform(ids, costs, 6).dollars
    assert lo == pytest.approx(exact, rel=1e-6)
