"""Policy reference implementations: behaviour + dollar accounting."""
import numpy as np
import pytest

from repro.core import (POLICIES, Trace, simulate, total_cost_no_cache,
                        zipf_trace)


def _uniform_trace(ids, n, costs=None):
    ids = np.asarray(ids, np.int32)
    tr = Trace(ids=ids, sizes=np.ones(n))
    c = np.ones(n) if costs is None else np.asarray(costs, float)
    return tr, c


def test_lru_classic_behaviour():
    # B=2, sequence 0 1 2 0: LRU evicts 0 at request of 2 -> 0 misses again
    tr, c = _uniform_trace([0, 1, 2, 0], 3)
    r = simulate("lru", tr, c, 2.0)
    assert r.misses == 4 and r.hits == 0
    # sequence 0 1 0 2 0: 0 is MRU when 2 arrives -> 1 evicted, 0 hits twice
    tr, c = _uniform_trace([0, 1, 0, 2, 0], 3)
    r = simulate("lru", tr, c, 2.0)
    assert r.hits == 2 and r.misses == 3


def test_belady_beats_lru_on_adversarial_loop():
    # cyclic access over B+1 objects: LRU gets 0 hits, Belady gets many
    n, B, laps = 5, 4, 40
    ids = np.tile(np.arange(n), laps)
    tr, c = _uniform_trace(ids, n)
    lru = simulate("lru", tr, c, float(B))
    bel = simulate("belady", tr, c, float(B))
    assert lru.hits == 0
    assert bel.hits > 0.5 * len(ids)


def test_gdsf_prefers_expensive_objects():
    # two objects alternate; cache of 1 page can't help (mandatory displace).
    # with B=2 and a third cold object streaming through, GDSF keeps the
    # expensive one cached while LRU cycles.
    ids = [0, 1] + [0, 2, 1] * 30
    costs = np.array([1.0, 1000.0, 1.0])
    tr, c = _uniform_trace(ids, 3, costs)
    gdsf = simulate("gdsf", tr, c, 2.0)
    lru = simulate("lru", tr, c, 2.0)
    assert gdsf.dollars < lru.dollars


def test_dollar_accounting_identity():
    tr = zipf_trace(n_objects=60, n_requests=800, seed=1)
    costs = np.abs(np.random.default_rng(0).lognormal(0, 1, 60))
    tr = Trace(ids=tr.ids, sizes=np.ones(60))
    for p in POLICIES:
        r = simulate(p, tr, costs, 8.0)
        assert r.hits + r.misses == tr.num_requests
        # dollars == sum of costs over missed requests
        assert 0 <= r.dollars <= total_cost_no_cache(tr, costs) + 1e-9


def test_oversized_object_fetch_through():
    tr = Trace(ids=np.array([0, 1, 0, 1], np.int32),
               sizes=np.array([10.0, 1000.0]))
    c = np.array([1.0, 5.0])
    r = simulate("lru", tr, c, 100.0)
    # object 1 can never be cached; object 0 hits on re-access
    assert r.dollars == pytest.approx(1.0 + 5.0 + 0.0 + 5.0)


def test_variable_size_eviction_until_fits():
    # capacity 10; object 2 (size 9) forces evicting both small ones
    tr = Trace(ids=np.array([0, 1, 2, 0, 1], np.int32),
               sizes=np.array([4.0, 4.0, 9.0]))
    c = np.ones(3)
    r = simulate("lru", tr, c, 10.0)
    assert r.misses == 5  # 0 and 1 evicted by 2, miss again
