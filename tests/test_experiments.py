"""Fast versions of the paper's headline experiments (full versions live in
benchmarks/; these guard the *claims* in CI time)."""
import numpy as np
import pytest

from benchmarks.bench_contention import run_frontier
from benchmarks.bench_heterogeneity import run_sweep
from repro.core import (PRICE_VECTORS, heterogeneity, miss_costs,
                        twemcache_like)


def _spearman(x, y):
    rx = np.argsort(np.argsort(x)).astype(float)
    ry = np.argsort(np.argsort(y)).astype(float)
    rx -= rx.mean(); ry -= ry.mean()
    d = np.sqrt((rx**2).sum() * (ry**2).sum())
    return float((rx * ry).sum() / d)


def test_heterogeneity_law():
    rows = run_sweep(n_points=10, T=1500, N=80, B=16)
    H = np.array([r[0] for r in rows])
    lru = np.array([r[1] for r in rows])
    gdsf = np.array([r[2] for r in rows])
    assert _spearman(H, lru) > 0.6          # paper: 0.87
    hi = H >= 0.5
    if hi.sum() >= 3:
        assert np.median(gdsf[hi]) < 0.6 * np.median(lru[hi])


def test_contention_frontier():
    rows, n_exp = run_frontier(n_exp=8, n_cheap=32, T=2500)
    d = dict(rows)
    # large regret below the frontier, collapse just past it (eq-2
    # mandatory-insertion semantics: frontier at N_exp + 1)
    assert d[n_exp - 2] > 0.1
    assert d[n_exp + 1] < 5e-3
    assert d[n_exp + 4] < 5e-3


def test_crossover_direction():
    """The price vector alone moves the workload across s*: H rises
    monotonically as s* falls (paper Table 1)."""
    tr = twemcache_like(n_requests=6000, seed=3)
    order = ["s3_cross_region", "s3_internet", "azure_internet",
             "gcs_internet"]
    hs = [heterogeneity(tr.ids, miss_costs(tr.sizes, PRICE_VECTORS[n]))
          for n in order]
    sstars = [PRICE_VECTORS[n].crossover_bytes for n in order]
    assert all(a >= b for a, b in zip(sstars, sstars[1:]))   # s* falls
    assert all(a <= b + 1e-9 for a, b in zip(hs, hs[1:]))    # H rises