"""Fleet governance: wire format, event-time windows, gossip, quorum swaps.

The acceptance scenario is the partitioned regime shift: 4 hosts hash-
partition a trace whose pricing flips mid-stream across s*, LRU wins the
fee-heavy phase on every partition and LFU wins the egress-heavy phase, so
a governed fleet that starts at LRU must quorum-swap after the flip to
match the best fixed policy.
"""
import math

import pytest

from repro.egress.cache import EgressCache, ONLINE_POLICIES, AccessEvent
from repro.egress.store import ObjectStore
from repro.fleet import (Fleet, FleetCoordinator, FleetNode, GossipState,
                         SimNetwork, WindowDelta, WireError,
                         access_event_from_json, access_event_to_json,
                         decode, decode_access_event, decode_window_delta,
                         encode_access_event, encode_window_delta,
                         hash_partition)
from repro.online import Watermark
from repro.online.scenario import regime_shift_scenario

# locked-in fleet regime-shift parameters (see benchmarks/bench_fleet.py:
# LRU wins phase A on every partition, LFU wins phase B by ~2x)
SCENARIO = dict(n_phase=3000, seed=0, n_big_active=12, big_bytes=1 << 18)
N_NODES = 4
FLEET_KW = dict(window_span=400.0, max_skew=32.0, gossip_every=100)


def _scenario():
    return regime_shift_scenario(**SCENARIO)


def _run_fixed_fleet(sc, policy, n=N_NODES):
    """Fleet of fixed-policy caches over the hash-partitioned trace."""
    store = sc.make_store()
    caches = [EgressCache(store, sc.capacity_bytes / n, policy,
                          consumer=f"edge{i}") for i in range(n)]
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        caches[hash_partition(key, n)].get(key)
    return math.fsum(c.meter.dollars for c in caches)


def _run_governed_fleet(sc, network=None, seed=1):
    store = sc.make_store()
    fleet = Fleet(store=store, n_nodes=N_NODES,
                  capacity_bytes=sc.capacity_bytes / N_NODES,
                  policy="lru", network=network, seed=seed, **FLEET_KW)
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        fleet.access(key, event_time=t)
    assert fleet.flush()
    return fleet


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def _event(mc=0.09 + 1e-10):
    return AccessEvent("obj/α-17", 123_456, False, mc, "gdsf", 42, 1234.5)


def test_access_event_binary_round_trip_bit_equal():
    ev = _event()
    back = decode_access_event(encode_access_event(ev))
    assert back == ev
    assert math.copysign(1, back.miss_cost) == math.copysign(1, ev.miss_cost)
    assert back.miss_cost.hex() == ev.miss_cost.hex()     # bit-equal


def test_access_event_json_round_trip_bit_equal():
    ev = _event(mc=0.1 + 0.2)      # classic non-representable decimal
    line = access_event_to_json(ev)
    assert access_event_from_json(line) == ev


def test_window_delta_round_trip():
    d = WindowDelta("edge3", 17, 9, 7231.0, 412,
                    {p: 0.001 * (i + 1) for i, p in enumerate(ONLINE_POLICIES)})
    assert decode_window_delta(encode_window_delta(d)) == d
    assert decode(encode_window_delta(d)) == d
    assert decode(encode_access_event(_event())) == _event()


def test_wire_rejects_corruption():
    frame = bytearray(encode_access_event(_event()))
    frame[10] ^= 0xFF
    with pytest.raises(WireError):
        decode_access_event(bytes(frame))
    with pytest.raises(WireError):
        decode_access_event(b"XX" + bytes(frame[2:]))     # bad magic
    with pytest.raises(WireError):
        decode_access_event(bytes(frame[:5]))             # truncated
    # kind mismatch: a valid WindowDelta frame is not an AccessEvent
    wd = encode_window_delta(WindowDelta("h", 0, 1, 0.0, 0, {}))
    with pytest.raises(WireError):
        decode_access_event(wd)


def test_wire_rejects_future_version():
    frame = bytearray(encode_access_event(_event()))
    frame[2] += 1                                         # bump version
    import binascii
    import struct
    frame[-4:] = struct.pack("<I", binascii.crc32(bytes(frame[:-4])))
    with pytest.raises(WireError, match="version"):
        decode_access_event(bytes(frame))


# ---------------------------------------------------------------------------
# watermark + node windows
# ---------------------------------------------------------------------------

def test_watermark_tolerates_bounded_skew_rejects_beyond():
    wm = Watermark(max_skew=5.0)
    wm.advance(10.0)
    wm.advance(6.0)                # late by 4 < 5: ok
    assert wm.value == 5.0
    assert wm.late == 1
    with pytest.raises(ValueError):
        wm.advance(4.0)            # late by 6 > 5: out of contract


def test_node_emits_contiguous_windows_and_replays_bill_bit_equal():
    store = ObjectStore("s3_internet")
    for i in range(8):
        store.put(f"o{i}", bytes(1000))
    node = FleetNode("edge0", store, 4000, "lru", window_span=10.0,
                     max_skew=2.0)
    # event times skip windows 2-3 entirely; skewed arrivals inside bound
    for t in [0, 1, 5, 12, 11, 14, 47, 46, 55]:
        node.access(f"o{t % 8}", float(t))
    node.flush()
    wids = [d.window_id for d in node.outbox]
    assert wids == sorted(wids) == list(range(wids[-1] + 1))   # contiguous
    empty = [d for d in node.outbox if d.events == 0]
    assert empty                                # quiet windows still emitted
    assert math.fsum(d.events for d in node.outbox) == 9
    assert node.replayed_dollars() == node.cache.meter.dollars  # bit-equal


# ---------------------------------------------------------------------------
# gossip
# ---------------------------------------------------------------------------

def test_sim_network_deterministic_per_seed():
    def run(seed):
        net = SimNetwork(seed, drop=0.3, duplicate=0.3, reorder=0.5,
                         max_delay=2)
        for i in range(50):
            net.send("a", "b", bytes([i]))
        out = []
        for _ in range(5):
            out.append([f[2] for f in net.deliver()])
        return out, net.snapshot()
    assert run(7) == run(7)
    assert run(7) != run(8)


def test_gossip_merge_idempotent_commutative():
    d1 = WindowDelta("h", 0, 1, 10.0, 5, {"lru": 0.5})
    d2 = WindowDelta("h", 0, 2, 12.0, 6, {"lru": 0.6})   # higher seq wins
    a, b = GossipState(), GossipState()
    assert a.merge(d1) and a.merge(d2) and not a.merge(d1)  # stale ignored
    assert b.merge(d2) and not b.merge(d1)
    assert a.digest() == b.digest()
    assert a.fleet_totals() == b.fleet_totals() == {"lru": 0.6}


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

def _delta(host, wid, dollars, seq=1):
    return WindowDelta(host, wid, seq, 0.0, 10, dollars)


def test_quorum_majority_swaps_and_never_double_applies():
    co = FleetCoordinator(3, policy="lru", hysteresis=0.1)
    better = {"lru": 1.0, "lfu": 0.5, "gds": 2.0, "gdsf": 2.0}
    for h in ("a", "b", "c"):
        co.ingest(_delta(h, 0, dict(better)))
    applied = co.poll()
    assert [s.new_policy for s in applied] == ["lfu"]
    assert co.policy == "lfu"
    # re-delivered evidence for the decided window is inert
    for h in ("a", "b", "c"):
        co.ingest(_delta(h, 0, dict(better), seq=2))
    assert co.poll() == [] and len(co.swaps) == 1


def test_quorum_waits_for_majority_and_in_order_windows():
    co = FleetCoordinator(4, policy="lru")      # quorum = 3
    win = {"lru": 1.0, "lfu": 0.1}
    co.ingest(_delta("a", 0, dict(win)))
    co.ingest(_delta("b", 0, dict(win)))
    assert co.poll() == []                      # 2 < quorum
    co.ingest(_delta("a", 1, dict(win)))
    co.ingest(_delta("b", 1, dict(win)))
    co.ingest(_delta("c", 1, dict(win)))
    assert co.poll() == []                      # window 0 gaps the order
    co.ingest(_delta("c", 0, dict(win)))
    swaps = co.poll()                           # both decide, one swap
    assert co.frontier == 1 and len(swaps) == 1


def test_split_vote_quorum_keeps_incumbent_central_breaks_tie():
    keep = {"lru": 1.0, "lfu": 0.99}
    move = {"lru": 1.0, "lfu": 0.1}
    for mode, expect in (("quorum", "lru"), ("central", "lfu")):
        co = FleetCoordinator(4, policy="lru", mode=mode, quorum=4)
        for h, d in zip("abcd", (keep, keep, move, move)):
            co.ingest(_delta(h, 0, dict(d)))
        co.poll()
        assert co.policy == expect, mode
        if mode == "central":
            assert co.swaps[0].mode == "tiebreak"


def test_zero_weight_windows_keep_incumbent():
    co = FleetCoordinator(2, policy="lru")
    for h in "ab":
        co.ingest(_delta(h, 0, {}))
    co.poll()
    assert co.policy == "lru" and co.frontier == 0 and not co.swaps


# ---------------------------------------------------------------------------
# the 4-node acceptance scenario
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shifted():
    sc = _scenario()
    fixed = {p: _run_fixed_fleet(sc, p) for p in ONLINE_POLICIES}
    fleet = _run_governed_fleet(sc)
    return sc, fixed, fleet


def test_fleet_regime_shift_quorum_swap_post_flip(shifted):
    sc, fixed, fleet = shifted
    flip_window = int(sc.flip_at // FLEET_KW["window_span"])
    assert len(fleet.swaps) == 1
    swap = fleet.swaps[0]
    assert swap.old_policy == "lru"
    # unanimous post-quorum policy across every node
    assert {n.cache.policy for n in fleet.nodes} == {fleet.policy} \
        == {swap.new_policy}
    # decided within one gossip round of the watermark passing the flip:
    # the flip window (or the one after, if the flip lands mid-window)
    assert flip_window <= swap.window_id <= flip_window + 1
    # and the swap target is the policy that actually wins post-flip
    assert swap.new_policy == min(fixed, key=fixed.get)


def test_fleet_dollars_within_10pct_of_best_fixed(shifted):
    sc, fixed, fleet = shifted
    best = min(fixed.values())
    assert fleet.dollars() <= 1.10 * best
    # and strictly better than the worst fixed policy (the flip has teeth)
    assert fleet.dollars() < max(fixed.values())


def test_fleet_billing_reconciles_bit_for_bit(shifted):
    _sc, _fixed, fleet = shifted
    # realized fleet bill == fsum of per-node audit observations, bit-equal
    audits = fleet.audits()
    assert fleet.dollars() == math.fsum(
        a.observed_dollars for a in audits.values())
    # each node's wire-log replay re-accrues its own meter bit-for-bit
    for node in fleet.nodes:
        assert node.replayed_dollars() == node.cache.meter.dollars
    # converged participants agree on fleet-wide shadow totals
    totals = fleet.fleet_shadow_totals()
    for node in fleet.nodes:
        assert node.state.fleet_totals() == totals


def test_fleet_under_faults_converges_no_double_swap():
    sc = _scenario()
    net = SimNetwork(seed=3, drop=0.25, duplicate=0.3, reorder=0.5,
                     max_delay=2)
    fleet = _run_governed_fleet(sc, network=net)
    assert net.dropped > 0 and net.duplicated > 0 and net.reordered > 0
    # anti-entropy healed the faults
    assert fleet.converged()
    # each window decided at most once -> swaps never double-apply
    wids = [s.window_id for s in fleet.swaps]
    assert len(wids) == len(set(wids))
    assert sorted(fleet.coordinator.decided) == \
        list(range(fleet.coordinator.frontier + 1))
    for node in fleet.nodes:
        assert node.cache.policy_swaps == len(fleet.swaps)
    # governance still lands the fleet on the post-flip winner
    assert {n.cache.policy for n in fleet.nodes} == {fleet.policy}
    # swap count stays bounded under faults (hysteresis prevents churn)
    assert len(fleet.swaps) <= 3


def test_fleet_snapshot_shapes():
    sc = _scenario()
    fleet = _run_governed_fleet(sc)
    snap = fleet.snapshot()
    assert snap["n_nodes"] == N_NODES
    assert set(snap["nodes"]) == {f"edge{i}" for i in range(N_NODES)}
    assert snap["coordinator"]["frontier"] >= 0
    assert snap["network"]["sent"] > snap["network"]["dropped"]
    assert snap["dollars"] == fleet.dollars()
