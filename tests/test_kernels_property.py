"""Hypothesis property tests for the Pallas kernels.

Guarded with `pytest.importorskip`: hypothesis is optional in the container,
and collection must not die where it is absent (the fixed-seed sweeps in
test_kernels.py cover the same oracles either way).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.trace import next_use_indices  # noqa: E402
from repro.kernels import ops  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_next_use_property(data):
    T = data.draw(st.integers(1, 300))
    N = data.draw(st.integers(1, 20))
    block = data.draw(st.sampled_from([16, 64, 128]))
    ids = np.array(data.draw(st.lists(st.integers(0, N - 1),
                                      min_size=T, max_size=T)), np.int32)
    got = np.asarray(ops.next_use(jnp.asarray(ids), N, block_t=block))
    np.testing.assert_array_equal(got, next_use_indices(ids, N))
