"""chunked (online-softmax) attention == full attention, all mask modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import attention, chunked_attention


@pytest.mark.parametrize("B,Sq,Sk,H,G,D,window,causal,q_off,k_off", [
    (2, 16, 16, 4, 2, 8, 0, True, 0, 0),
    (1, 32, 32, 4, 1, 16, 8, True, 0, 0),
    (2, 8, 24, 6, 2, 8, 0, True, 16, 0),      # decode-ish with offset
    (1, 16, 16, 2, 2, 8, 0, False, 0, 0),     # bidirectional (whisper enc)
    (1, 4, 12, 4, 4, 8, 6, True, 9, -2),      # shift cache w/ neg k_offset
    (2, 40, 40, 8, 4, 16, 0, True, 0, 0),
])
def test_chunked_matches_full(B, Sq, Sk, H, G, D, window, causal, q_off, k_off):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, G, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, G, D)), jnp.float32)
    kw = dict(causal=causal, window=window, q_offset=q_off, k_offset=k_off)
    full = attention(q, k, v, **kw)
    for chunk in (4, 8, 16):
        ck = chunked_attention(q, k, v, kv_chunk=chunk, **kw)
        np.testing.assert_allclose(np.asarray(full), np.asarray(ck),
                                   rtol=2e-4, atol=2e-4)


def test_softcap_agrees():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    a = attention(q, k, v, logit_softcap=30.0)
    b = chunked_attention(q, k, v, logit_softcap=30.0, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)