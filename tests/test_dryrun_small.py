"""Dry-run machinery on a mini 8-device host mesh (subprocess: the device
count must be set before jax initializes, so this can't run in-process)."""
import json
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.models.common import set_activation_mesh
    from repro.parallel.sharding import make_rules, params_sharding, batch_spec
    from repro.train.optim import OptimizerConfig, make_optimizer
    from repro.train.trainer import make_train_step, train_state_shardings
    from repro.launch.hlo_analysis import analyze_collectives, cost_analysis_dict
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    set_activation_mesh(mesh)
    cfg = get_config("gemma3-4b", smoke=True)
    model = get_model(cfg)
    rules = make_rules(mesh)
    opt = make_optimizer(OptimizerConfig())
    ps, osd, ap, aos = train_state_shardings(rules, model, opt)
    step = make_train_step(model, opt, microbatches=2, grad_shardings=ps)
    batch = model.train_inputs(8, 32)
    bs = batch_spec(rules, batch)
    with mesh:
        lowered = jax.jit(step, in_shardings=(ps, osd, bs),
                          out_shardings=(NamedSharding(mesh, P()), ps, osd),
                          donate_argnums=(0, 1)).lower(ap, aos, batch)
        compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    cs = analyze_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    print(json.dumps({
        "flops": float(ca.get("flops", 0.0)),
        "coll_bytes": cs.total_bytes,
        "coll_count": cs.total_count,
        "temp_bytes": ma.temp_size_in_bytes,
    }))
""")


def test_mini_mesh_dryrun():
    out = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 1e6            # real per-device work counted
    assert rec["coll_count"] > 0         # SPMD emitted collectives
    assert rec["coll_bytes"] > 0
    assert rec["temp_bytes"] > 0