"""Batched serving engine with an egress-billed prefix cache.

The serving-side instantiation of the paper: decoded prefixes' KV blocks
are objects in cloud storage (billed per GET + per byte when re-fetched);
a local EgressCache with a dollar-aware policy decides which prefix KVs
stay resident. `audit()` measures the engine's realized dollar-regret
against the exact offline reference.

The engine itself is a straightforward continuous-batching loop over the
model's prefill/decode steps — adequate for the examples; the dry-run
exercises the production shapes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore
from repro.fleet import Fleet
from repro.models.registry import ModelApi
from repro.online import DollarGovernor, MetricsRegistry, WindowedAuditor

__all__ = ["ServeEngine", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 8
    output: Optional[np.ndarray] = None


def _prefix_key(tokens: np.ndarray) -> str:
    return "prefix/" + hashlib.sha1(tokens.tobytes()).hexdigest()[:16]


class ServeEngine:
    def __init__(self, model: ModelApi, params,
                 store: Optional[ObjectStore] = None,
                 prefix_cache_bytes: float = 1 << 24,
                 policy: str = "gdsf", govern: bool = False,
                 governor_window: int = 64, hysteresis: float = 0.05,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer=None, events=None, fleet_nodes: int = 0):
        self.model = model
        self.params = params
        self.store = store or ObjectStore("gcs_internet")
        self.metrics = metrics or MetricsRegistry()
        # observability (DESIGN.md §9): one tracer threads through engine ->
        # cache -> store so request/cache.get/store.get spans nest; the
        # decision event log rides on the cache
        self.tracer = tracer
        self.events = events
        if tracer is not None:
            self.store.set_tracer(tracer)
        # fleet mode (DESIGN.md §10): partition the prefix cache across
        # `fleet_nodes` hash-sharded hosts, each with its own billing meter
        # and shadow panel, governed by quorum swaps over gossip; the
        # single-host cache and governor are replaced wholesale
        assert not (govern and fleet_nodes), \
            "govern= and fleet_nodes= are mutually exclusive governors"
        self.fleet: Optional[Fleet] = None
        self.cache: Optional[EgressCache] = None
        if fleet_nodes:
            self.fleet = Fleet(
                store=self.store, n_nodes=fleet_nodes,
                capacity_bytes=prefix_cache_bytes / fleet_nodes,
                policy=policy, window_span=4.0 * governor_window,
                max_skew=float(governor_window),
                gossip_every=governor_window,
                events=events, metrics=self.metrics)
        else:
            self.cache = EgressCache(self.store, prefix_cache_bytes, policy,
                                     consumer="serve_prefix_cache",
                                     metrics=self.metrics, tracer=tracer,
                                     events=events)
        self.governor: Optional[DollarGovernor] = None
        if govern:
            auditor = WindowedAuditor(prefix_cache_bytes,
                                      window=4 * governor_window,
                                      metrics=self.metrics)
            self.governor = DollarGovernor(
                self.cache, window=governor_window, hysteresis=hysteresis,
                auditor=auditor, metrics=self.metrics)
        self._decode = jax.jit(
            lambda p, t, c, i: model.decode_step(p, t, c, i))

    # ------------------------------------------------------------------
    def _prefill_batch(self, prompts: np.ndarray):
        """Run prefill; persist each row's prefix KV to the object store so
        identical prefixes can be re-fetched (billed) or served from the
        local egress cache."""
        logits, caches = self.model.prefill(
            self.params, {"tokens": jnp.asarray(prompts)})
        for b in range(prompts.shape[0]):
            key = _prefix_key(prompts[b])
            if not self.store.contains(key):
                # store one row's KV bytes (serialized, billed on re-fetch)
                row = [np.asarray(kv[0][b]) for kv in caches]
                blob = b"".join(r.tobytes() for r in row)
                self.store.put(key, blob)
        return logits, caches

    def _span(self, name: str, **attrs):
        """Engine-level span, or a nullcontext when tracing is off."""
        if not self.tracer:
            return contextlib.nullcontext()
        return self.tracer.span(name, cat="serve", **attrs)

    def serve(self, requests: list[Request]) -> list[Request]:
        """Batch requests of equal prompt length and decode greedily."""
        with self._span("serve.batch", requests=len(requests)):
            self._serve(requests)
        self.metrics.inc("serve.requests", len(requests))
        return requests

    def _serve(self, requests: list[Request]) -> None:
        by_len: dict[int, list[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        for _, group in sorted(by_len.items()):
            prompts = np.stack([r.prompt for r in group])
            # prefix-cache touch: hit = KV stays local, miss = billed fetch
            for r in group:
                key = _prefix_key(r.prompt)
                if self.store.contains(key):
                    with self._span("serve.request", rid=r.rid):
                        if self.fleet is not None:
                            self.fleet.access(key)
                        else:
                            self.cache.get(key)
            with self._span("serve.prefill", batch=len(group)):
                logits, caches = self._prefill_batch(prompts)
            S = prompts.shape[1]
            max_new = max(r.max_new_tokens for r in group)
            caches = _grow(self.model, caches, S + max_new)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs = [tok]
            with self._span("serve.decode", batch=len(group), steps=max_new):
                for step in range(max_new - 1):
                    logits, caches = self._decode(self.params, tok, caches,
                                                  jnp.int32(S + step))
                    tok = jnp.argmax(logits, -1).astype(jnp.int32)
                    outs.append(tok)
            gen = np.stack([np.asarray(t) for t in outs], 1)
            for i, r in enumerate(group):
                r.output = gen[i][:r.max_new_tokens]

    def audit(self):
        """Exact offline audit: per-host dict in fleet mode (each host's
        own partition trace), single audit otherwise."""
        if self.fleet is not None:
            return self.fleet.audits()
        return self.cache.audit()

    def governance_snapshot(self) -> dict:
        """Metrics + governor + obs state, the JSON-exportable view."""
        snap = dict(metrics=self.metrics.snapshot(),
                    store=self.store.meter.snapshot(),
                    consumers=self.store.consumer_snapshot())
        if self.governor is not None:
            snap["governor"] = self.governor.snapshot()
        if self.fleet is not None:
            snap["fleet"] = self.fleet.snapshot()
        if self.events is not None:
            snap["events"] = self.events.snapshot()
        if self.tracer:
            snap["spans"] = self.tracer.to_dicts()
        return snap


def _grow(model: ModelApi, caches, max_len: int):
    cfg = model.cfg
    if cfg.family in ("dense", "moe", "vlm"):
        out = []
        for (k, v) in caches:
            pad = max_len - k.shape[1]
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            out.append((k, v))
        return out
    if cfg.family == "encdec":
        out = []
        for (sk, sv, ck, cv) in caches:
            pad = max_len - sk.shape[1]
            if pad > 0:
                sk = jnp.pad(sk, ((0, 0), (0, pad), (0, 0), (0, 0)))
                sv = jnp.pad(sv, ((0, 0), (0, pad), (0, 0), (0, 0)))
            out.append((sk, sv, ck, cv))
        return out
    return caches