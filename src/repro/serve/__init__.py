# Serving layer: continuous-batching engine with an egress-billed prefix
# cache, optionally governed by the online dollar-governor.
from .engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
