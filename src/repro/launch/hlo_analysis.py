"""Post-optimization HLO analysis: collective bytes per device.

cost_analysis() gives FLOPs and memory bytes but NOT collective traffic;
we parse compiled.as_text() instead (the prompt's prescribed method).

Accounting rules:
  * every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute instruction contributes its RESULT-shape bytes
    (per-device, since the module is the SPMD per-device program);
  * instructions inside a while body count once per trip — the trip count
    is recovered from the integer constant in the while condition
    (lax.scan lowers to a while loop with a `constant(T)` bound);
  * nested whiles multiply.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["CollectiveStats", "analyze_collectives", "cost_analysis_dict"]


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict across jax versions (older
    releases return a one-element list of dicts, one per partition)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, e.g. 'f32[2,512,1024]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count_by_kind.values()))


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines.

    A computation header is any line ending in '{' with a '->' return
    annotation (param lists may contain nested tuple parens, so we only
    anchor on the name prefix)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.endswith("{") and "->" in ls and not ls.startswith("ROOT"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", ls)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if ls == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(ls)
    return comps


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)\s*\(", hlo)
    return m.group(1) if m else None


_COLL_OP_RE = re.compile(
    r"\b(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")


def _local_collectives(lines: list[str]):
    by_b: dict[str, float] = defaultdict(float)
    by_c: dict[str, int] = defaultdict(int)
    for ls in lines:
        if "=" not in ls:
            continue
        m = _COLL_OP_RE.search(ls)
        if not m:
            continue
        base, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            continue  # counted at -start
        # result-type bytes: everything left of the opcode token holds the
        # instruction name (no brackets) and the result shape(s)
        b = _shape_bytes(ls[:m.start()])
        if suffix == "-start":
            b /= 2  # async start results pair (aliased input, output)
        by_b[base] += b
        by_c[base] += 1
    return by_b, by_c


def _calls(lines: list[str]):
    """(callee, kind) pairs: while bodies/conditions, calls, fusions."""
    out = []
    for ls in lines:
        for m in re.finditer(r"(body|condition|to_apply|calls)=%?([\w\.\-]+)",
                             ls):
            out.append((m.group(2), m.group(1)))
    return out


def _trip_count(cond_lines: list[str]) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    best = 1
    for ls in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ls):
            best = max(best, int(m.group(1)))
    return best


def analyze_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    memo: dict[str, tuple[dict, dict]] = {}

    def visit(name: str, stack=()) -> tuple[dict, dict]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}, {}
        lines = comps[name]
        by_b, by_c = _local_collectives(lines)
        by_b, by_c = dict(by_b), dict(by_c)
        # find whiles: while(...) , condition=%c, body=%b
        for ls in lines:
            if re.search(r"\bwhile\(", ls):
                bm = re.search(r"body=%?([\w\.\-]+)", ls)
                cm = re.search(r"condition=%?([\w\.\-]+)", ls)
                if not bm:
                    continue
                trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                bb, bc = visit(bm.group(1), stack + (name,))
                for k, v in bb.items():
                    by_b[k] = by_b.get(k, 0) + v * trips
                for k, v in bc.items():
                    by_c[k] = by_c.get(k, 0) + v * trips
            else:
                for callee, kind in _calls([ls]):
                    if kind in ("body", "condition"):
                        continue  # handled via while above
                    bb, bc = visit(callee, stack + (name,))
                    for k, v in bb.items():
                        by_b[k] = by_b.get(k, 0) + v
                    for k, v in bc.items():
                        by_c[k] = by_c.get(k, 0) + v
        memo[name] = (by_b, by_c)
        return memo[name]

    if entry is None:
        # fall back: count everything flat
        by_b, by_c = _local_collectives(hlo.splitlines())
        return CollectiveStats(dict(by_b), dict(by_c))
    by_b, by_c = visit(entry)
    return CollectiveStats(by_b, by_c)