import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# This override exists ONLY for the dry-run (assignment spec); smoke tests
# and benchmarks see the real single CPU device.

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
# For each cell this proves the distribution config is coherent (sharding
# resolves, collectives lower, memory fits) and extracts the roofline terms:
#
#   python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh multi
#   python -m repro.launch.dryrun --all --out results/dryrun.jsonl
#
# Output: one JSON record per cell (memory_analysis, cost_analysis, collective
# bytes by kind, roofline terms). EXPERIMENTS.md §Dry-run/§Roofline read these.

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch.hlo_analysis import analyze_collectives, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import count_params, model_flops, terms_from_analysis
from repro.models.registry import get_model
from repro.parallel.sharding import (batch_spec, kv_cache_sharding, make_rules,
                                     params_sharding)
from repro.train.optim import OptimizerConfig, make_optimizer
from repro.train.trainer import make_train_step, train_state_shardings

# optimizer-state memory is the binding constraint at 1T params (DESIGN.md §5)
OPTIMIZER_OVERRIDES = {
    "kimi-k2-1t-a32b": OptimizerConfig(name="adafactor"),
    "qwen2-vl-72b": OptimizerConfig(name="adamw", moment_dtype=jnp.bfloat16),
}
DEFAULT_OPT = OptimizerConfig(name="adamw")


def _opt_for(arch: str):
    return make_optimizer(OPTIMIZER_OVERRIDES.get(arch, DEFAULT_OPT))


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _lower_opt_probe(opt, ap, ps, osd, mesh):
    """Standalone optimizer-update program (counted once per real step)."""
    import jax.numpy as _jnp
    grads = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, _jnp.bfloat16), ap)
    aos = jax.eval_shape(opt.init, ap)

    def upd(g, s, p):
        return opt.update(g, s, p)

    fn = jax.jit(upd, in_shardings=(ps, osd, ps),
                 out_shardings=(ps, osd), donate_argnums=(1, 2))
    return fn.lower(grads, aos, ap)


# grad-accumulation per train cell so activations fit 16 GB/chip
# (EXPERIMENTS.md §Dry-run documents the napkin math per arch)
MICROBATCH_OVERRIDES = {
    "default": 4,
    "xlstm-125m": 1,
    "qwen2-moe-a2.7b": 4,
    "gemma3-4b": 4,
    "whisper-large-v3": 4,
    "phi4-mini-3.8b": 8,
    "chatglm3-6b": 8,
    "recurrentgemma-9b": 8,
    "mistral-nemo-12b": 8,
    "qwen2-vl-72b": 16,
    "kimi-k2-1t-a32b": 16,
}
# the 1T cell can't afford an f32 grad accumulator (16 GB/chip alone)
ACCUM_DTYPE_OVERRIDES = {"kimi-k2-1t-a32b": jnp.bfloat16}


def lower_cell(arch: str, shape_id: str, mesh, *, moe_ep: bool = False,
               microbatches: int | None = None):
    """Returns (lowered, meta, probe) for one cell."""
    cfg = get_config(arch)
    model = get_model(cfg)
    rules = make_rules(mesh, moe_ep=moe_ep)
    shape = SHAPES[shape_id]
    kind = shape["kind"]
    B, S = shape["global_batch"], shape["seq_len"]
    dp = _dp_axes(mesh)
    if microbatches is None:
        microbatches = MICROBATCH_OVERRIDES.get(
            arch, MICROBATCH_OVERRIDES["default"])
        # keep every DP shard busy: at least one row per shard per microbatch
        from repro.parallel.sharding import mesh_axis_size
        microbatches = max(1, min(microbatches, B // mesh_axis_size(mesh, dp)))

    # probes reconstruct true per-step cost from scanned programs
    # (cost_analysis counts a while body ONCE; see run_cell):
    #   dense:  T = mb*P - (mb-1)*O
    #   moe:    T = mb*P + mb*(n_tail-1)*L1 - (mb-1)*O
    probes = {}
    accum_dtype = ACCUM_DTYPE_OVERRIDES.get(arch, jnp.float32)
    scan_layers = cfg.num_experts > 0 or (
        cfg.family in ("dense", "vlm", "moe") and cfg.num_layers >= 48)
    if kind == "train" and scan_layers:
        # Giants (MoE or >=48 homogeneous layers) train with the
        # scan-layers layout (compile-time at fleet scale; see
        # models/transformer.py). Roofline FLOPs use the hybrid
        # accounting: scan program counts the body once, the standalone
        # per-layer probe supplies the remaining (n-1) layers.
        from repro.models import transformer as tfm
        from repro.models.common import abstract_params, axes_tree
        opt = _opt_for(arch)
        defs = tfm.stacked_param_defs(cfg)
        ap = abstract_params(defs, cfg.param_dtype)
        ax = axes_tree(defs)
        ps = params_sharding(rules, ap, ax)
        aos = jax.eval_shape(opt.init, ap)
        from repro.train.trainer import opt_state_sharding
        osd = opt_state_sharding(rules, opt, ap, ax)

        step = make_train_step(
            model, opt, microbatches=microbatches, accum_dtype=accum_dtype,
            grad_shardings=ps,
            loss_override=lambda p, b: tfm.loss_fn_scanned(cfg, p, b))
        batch = model.train_inputs(B, S)
        bs = batch_spec(rules, batch)
        fn = jax.jit(step, in_shardings=(ps, osd, bs),
                     out_shardings=(NamedSharding(mesh, P()), ps, osd),
                     donate_argnums=(0, 1))
        lowered = fn.lower(ap, aos, batch)
        # per-layer fwd+bwd probe (at MICRO batch size) for layer-scan cost
        Bm = B // microbatches
        ldefs = tfm.layer_defs(cfg, cfg.first_k_dense)
        lap = abstract_params(ldefs, cfg.param_dtype)
        lps = params_sharding(rules, lap, axes_tree(ldefs))
        dp_b = rules._fit(Bm, dp)
        x_sds = jax.ShapeDtypeStruct((Bm, S, cfg.d_model), cfg.param_dtype)
        if cfg.mrope_sections:   # VLM: three position streams
            pos_sds = jax.ShapeDtypeStruct((3, Bm, S), jnp.int32)
            pos_sh = NamedSharding(mesh, P(None, dp_b, None))
        else:
            pos_sds = jax.ShapeDtypeStruct((Bm, S), jnp.int32)
            pos_sh = NamedSharding(mesh, P(dp_b, None))
        pfn = jax.jit(tfm.layer_fwdbwd_probe(cfg, cfg.first_k_dense),
                      in_shardings=(lps,
                                    NamedSharding(mesh, P(dp_b, None, None)),
                                    pos_sh))
        n_tail = cfg.num_layers - cfg.first_k_dense
        probes["layer"] = (pfn.lower(lap, x_sds, pos_sds),
                           microbatches * (n_tail - 1))
        if microbatches > 1:
            probes["opt"] = (_lower_opt_probe(opt, ap, ps, osd, mesh),
                             -(microbatches - 1))
    elif kind == "train":
        opt = _opt_for(arch)
        ps, osd, ap, aos = train_state_shardings(rules, model, opt)
        step = make_train_step(model, opt, microbatches=microbatches,
                               accum_dtype=accum_dtype, grad_shardings=ps)
        batch = model.train_inputs(B, S)
        bs = batch_spec(rules, batch)
        fn = jax.jit(step,
                     in_shardings=(ps, osd, bs),
                     out_shardings=(NamedSharding(mesh, P()), ps, osd),
                     donate_argnums=(0, 1))
        lowered = fn.lower(ap, aos, batch)
        if microbatches > 1:
            probes["opt"] = (_lower_opt_probe(opt, ap, ps, osd, mesh),
                             -(microbatches - 1))
    elif kind == "prefill":
        ap = model.abstract()
        ps = params_sharding(rules, ap, model.axes())
        batch = model.prefill_inputs(B, S)
        bs = batch_spec(rules, batch)
        abstract_caches = jax.eval_shape(
            lambda p, b: model.prefill(p, b)[1], ap, batch)
        cache_sh = kv_cache_sharding(rules, abstract_caches)
        logits_sh = NamedSharding(mesh, P(rules._fit(B, dp), None))
        fn = jax.jit(lambda p, b: model.prefill(p, b),
                     in_shardings=(ps, bs),
                     out_shardings=(logits_sh, cache_sh))
        lowered = fn.lower(ap, batch)
    elif kind == "decode":
        ap = model.abstract()
        ps = params_sharding(rules, ap, model.axes())
        caches = model.abstract_caches(B, S)
        cache_sh = kv_cache_sharding(rules, caches)
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        tok_sh = NamedSharding(mesh, P(rules._fit(B, dp)))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        pos_sh = NamedSharding(mesh, P())
        logits_sh = NamedSharding(mesh, P(rules._fit(B, dp), None))
        fn = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i),
                     in_shardings=(ps, tok_sh, cache_sh, pos_sh),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(2,))
        lowered = fn.lower(ap, tok, caches, pos)
    else:
        raise ValueError(kind)

    # model-level FLOP accounting for the useful-compute ratio
    total, active, embed = count_params(model.abstract(), model.axes(),
                                        top_k=cfg.top_k,
                                        num_experts=cfg.num_experts)
    tokens = B * S if kind in ("train", "prefill") else B
    mf = model_flops(kind, active, tokens)
    prog_mult = microbatches if kind == "train" else 1
    meta = dict(arch=arch, shape=shape_id, kind=kind, global_batch=B,
                seq_len=S, params_total=total, params_active=active,
                params_embed=embed, model_flops=mf,
                microbatches=microbatches, program_multiplier=prog_mult)
    return lowered, meta, probes


def run_cell(arch: str, shape_id: str, *, multi_pod: bool, moe_ep=False,
             microbatches=None):
    from repro.models.common import set_activation_mesh
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_activation_mesh(mesh)
    n_dev = mesh.size
    rec = dict(mesh="multi" if multi_pod else "single", devices=n_dev,
               moe_ep=moe_ep)
    t0 = time.time()
    with mesh:
        lowered, meta, probes = lower_cell(arch, shape_id, mesh,
                                           moe_ep=moe_ep,
                                           microbatches=microbatches)
        rec.update(meta)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        extra_flops = extra_bytes = 0.0
        rec["probes"] = {}
        for pname, (plow, mult) in probes.items():
            pc = cost_analysis_dict(plow.compile())
            pf = float(pc.get("flops", 0.0))
            pb = float(pc.get("bytes accessed", 0.0))
            extra_flops += pf * mult
            extra_bytes += pb * mult
            rec["probes"][pname] = dict(multiplier=mult, flops=pf, bytes=pb)
    rec["lower_s"] = round(t1 - t0, 1)
    rec["compile_s"] = round(t2 - t1, 1)
    ma = compiled.memory_analysis()
    rec["memory"] = dict(
        argument_gib=ma.argument_size_in_bytes / 2**30,
        output_gib=ma.output_size_in_bytes / 2**30,
        temp_gib=ma.temp_size_in_bytes / 2**30,
        alias_gib=ma.alias_size_in_bytes / 2**30,
        peak_gib=(ma.argument_size_in_bytes + ma.output_size_in_bytes
                  + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
    )
    ca = cost_analysis_dict(compiled)
    pm = rec.get("program_multiplier", 1)
    flops = float(ca.get("flops", 0.0)) * pm + extra_flops
    byts = float(ca.get("bytes accessed", 0.0)) * pm + extra_bytes
    rec["cost"] = dict(flops_per_device=flops, bytes_per_device=byts,
                       program_flops=float(ca.get("flops", 0.0)))
    hlo = compiled.as_text()
    cs = analyze_collectives(hlo)
    rec["collectives"] = dict(bytes_by_kind=cs.bytes_by_kind,
                              count_by_kind=cs.count_by_kind,
                              total_bytes=cs.total_bytes)
    rt = terms_from_analysis(flops, byts, cs.total_bytes, n_dev,
                             rec["model_flops"])
    rec["roofline"] = rt.as_dict()
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel MoE variant (perf experiment)")
    ap.add_argument("--microbatch", type=int, default=None,
                    help="override grad-accumulation microbatches")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    todo = []
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        for a, s in cells():
            for m in meshes:
                todo.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            todo.append((args.arch, args.shape, m))

    outpath = pathlib.Path(args.out) if args.out else None
    done = set()
    if outpath and outpath.exists() and args.skip_existing:
        for line in outpath.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("moe_ep", False)))
            except json.JSONDecodeError:
                pass

    for arch, shape_id, multi in todo:
        key = (arch, shape_id, "multi" if multi else "single", args.moe_ep)
        if key in done:
            print(f"SKIP {key}")
            continue
        print(f"=== {arch} x {shape_id} x "
              f"{'multi' if multi else 'single'} ===", flush=True)
        try:
            rec = run_cell(arch, shape_id, multi_pod=multi,
                           moe_ep=args.moe_ep, microbatches=args.microbatch)
            print(f"  ok compile={rec['compile_s']}s "
                  f"peak={rec['memory']['peak_gib']:.2f}GiB "
                  f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                  f"coll={rec['collectives']['total_bytes']:.3e}B "
                  f"dominant={rec['roofline']['dominant']}", flush=True)
        except Exception as e:
            rec = dict(arch=arch, shape=shape_id,
                       mesh="multi" if multi else "single",
                       moe_ep=args.moe_ep, ok=False, error=str(e),
                       traceback=traceback.format_exc()[-2000:])
            print(f"  FAIL {e}", flush=True)
        if outpath:
            with outpath.open("a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()