"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.jsonl.

    PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import pathlib
import sys


def load(path):
    recs = []
    for line in pathlib.Path(path).read_text().splitlines():
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    # dedupe: keep the latest record per cell key
    by_key = {}
    for r in recs:
        by_key[(r.get("arch"), r.get("shape"), r.get("mesh"),
                r.get("moe_ep", False))] = r
    return list(by_key.values())


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def roofline_table(recs, mesh="single"):
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| peak GiB | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for r in sorted(recs, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if not r.get("ok") or r.get("mesh") != mesh or r.get("moe_ep"):
            continue
        rt = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rt['compute_s']:.3e} "
            f"| {rt['memory_s']:.3e} | {rt['collective_s']:.3e} "
            f"| **{rt['dominant']}** | {r['memory']['peak_gib']:.1f} "
            f"| {rt['useful_ratio']:.2f} | {rt['roofline_fraction']:.2f} |")
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | ok | compile s | peak GiB | flops/dev "
            "| coll B/dev | collective mix |", "|" + "---|" * 9]
    for r in sorted(recs, key=lambda r: (r.get("arch", ""), r.get("shape", ""),
                                         r.get("mesh", ""))):
        if r.get("moe_ep"):
            continue
        if not r.get("ok"):
            rows.append(f"| {r.get('arch')} | {r.get('shape')} "
                        f"| {r.get('mesh')} | FAIL | - | - | - | - | "
                        f"{str(r.get('error'))[:60]} |")
            continue
        mix = ",".join(f"{k.split('-')[-1]}:{v:.1e}" for k, v in
                       sorted(r["collectives"]["bytes_by_kind"].items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['compile_s']} | {r['memory']['peak_gib']:.1f} "
            f"| {r['cost']['flops_per_device']:.2e} "
            f"| {r['collectives']['total_bytes']:.2e} | {mix} |")
    return "\n".join(rows)


def pick_hillclimb(recs):
    """worst roofline fraction / most collective-bound / paper-representative."""
    ok = [r for r in recs if r.get("ok") and r["mesh"] == "single"
          and not r.get("moe_ep")]
    if not ok:
        return {}
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    return {"worst_roofline": (worst["arch"], worst["shape"]),
            "most_collective": (coll["arch"], coll["shape"])}


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    recs = load(path)
    n_ok = sum(1 for r in recs if r.get("ok"))
    print(f"## §Dry-run ({n_ok}/{len(recs)} cells ok)\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16x16, per device)\n")
    print(roofline_table(recs, "single"))
    print("\n## §Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "multi"))
    print("\nhillclimb candidates:", pick_hillclimb(recs))


if __name__ == "__main__":
    main()