"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).

  single-pod:  (16, 16)      -> ("data", "model")       256 chips
  multi-pod:   (2, 16, 16)   -> ("pod", "data", "model") 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "make_production_mesh", "make_host_mesh"]


def _axis_type_kwargs(n_axes: int) -> dict:
    """`axis_types` kwarg for `jax.make_mesh`, across JAX versions.

    `jax.sharding.AxisType` (explicit-sharding API) only exists from
    jax 0.5.x; older versions default every axis to Auto, which is what
    we request anyway — so omit the kwarg there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """`jax.make_mesh` with all axes in Auto mode, version-compatible."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke/examples (same axis names)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
