"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).

  single-pod:  (16, 16)      -> ("data", "model")       256 chips
  multi-pod:   (2, 16, 16)   -> ("pod", "data", "model") 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke/examples (same axis names)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (1, n), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)