"""Roofline terms from the compiled dry-run artifact (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per the assignment):
  peak_flops = 197e12 FLOP/s bf16 per chip
  hbm_bw     = 819e9  B/s per chip
  ici_bw     = 50e9   B/s per link

cost_analysis() on the SPMD-partitioned module reports *per-device* FLOPs
and bytes (validated against analytic matmul counts in the probe run, ±0.5%),
so the three terms are:

  compute    = flops_per_device / peak_flops
  memory     = bytes_per_device / hbm_bw
  collective = collective_bytes_per_device / ici_bw
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

__all__ = ["RooflineTerms", "terms_from_analysis", "count_params",
           "model_flops", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]


@dataclasses.dataclass
class RooflineTerms:
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO flops x devices)
    roofline_fraction: float     # compute_s / max(all terms)

    def as_dict(self):
        return dataclasses.asdict(self)


def terms_from_analysis(flops_dev: float, bytes_dev: float,
                        coll_bytes_dev: float, num_devices: int,
                        model_flops_total: float) -> RooflineTerms:
    c = flops_dev / PEAK_FLOPS
    m = bytes_dev / HBM_BW
    k = coll_bytes_dev / ICI_BW
    terms = {"compute": c, "memory": m, "collective": k}
    dominant = max(terms, key=terms.get)
    bound = max(c, m, k)
    hlo_total = flops_dev * num_devices
    return RooflineTerms(
        flops_dev=flops_dev, bytes_dev=bytes_dev,
        coll_bytes_dev=coll_bytes_dev,
        compute_s=c, memory_s=m, collective_s=k, dominant=dominant,
        model_flops_total=model_flops_total,
        useful_ratio=(model_flops_total / hlo_total) if hlo_total else 0.0,
        roofline_fraction=(c / bound) if bound > 0 else 0.0,
    )


def count_params(abstract_params, axes_tree, *, top_k: int = 0,
                 num_experts: int = 0) -> tuple[float, float]:
    """(total, active) parameter counts; embedding/unembedding excluded from
    `active` FLOP accounting the standard way (returned totals include them
    separately)."""
    import jax

    total = 0.0
    active = 0.0
    embed = 0.0

    leaves_p = jax.tree.leaves(abstract_params)
    leaves_a = jax.tree.leaves(axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    for p, ax in zip(leaves_p, leaves_a):
        n = float(np.prod(p.shape))
        total += n
        if isinstance(ax, tuple) and "vocab" in ax:
            embed += n
            continue
        if isinstance(ax, tuple) and "expert" in ax and num_experts > 0:
            active += n * (top_k / num_experts)
        else:
            active += n
    return total, active, embed


def model_flops(kind: str, n_active_nonembed: float, tokens: float) -> float:
    """6ND for training, 2ND for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_nonembed * tokens