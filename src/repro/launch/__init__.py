# Launch layer: device meshes, compiled dry-runs, and roofline/HLO
# analysis of the lowered cells. `dryrun` and `report` stay script-style
# entry points (python -m repro.launch.dryrun / .report).
from .hlo_analysis import (CollectiveStats, analyze_collectives,
                           cost_analysis_dict)
from .mesh import make_host_mesh, make_mesh, make_production_mesh
from .roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, RooflineTerms,
                       count_params, model_flops, terms_from_analysis)

__all__ = ["make_mesh", "make_production_mesh", "make_host_mesh",
           "CollectiveStats", "analyze_collectives", "cost_analysis_dict",
           "RooflineTerms", "terms_from_analysis", "count_params",
           "model_flops", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
