"""Dollar-governor: hot-swaps the live cache's policy on shadow evidence.

Subscribes to an `EgressCache`'s access stream and drives three organs:

  * the shadow-policy panel (`shadow.py`) — counterfactual dollars for the
    full online policy set, $0 of extra egress;
  * the windowed exact audit (`window.py`) — a live OPT-dollars bracket
    and regret estimate over recent traffic;
  * the swap rule — every `window` accesses, compare each policy's
    *windowed* shadow dollars; if the best shadow undercuts the incumbent
    policy's shadow by more than `hysteresis` (relative), hot-swap the
    live cache via `set_policy` (contents preserved, $0 to swap).

Comparisons are shadow-vs-shadow (the incumbent's own shadow, not the live
meter): all shadows start equally cold when the governor attaches and see
identical traffic, so a swap decision is never polluted by warm-up
asymmetry or by the live cache's admission controller.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.egress.cache import ONLINE_POLICIES, AccessEvent, EgressCache
from .shadow import ShadowPanel
from .window import WindowedAuditor

__all__ = ["DollarGovernor", "SwapEvent"]


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    clock: int                  # live-cache clock at the swap
    old_policy: str
    new_policy: str
    window_dollars: dict        # policy -> dollars over the deciding window


class DollarGovernor:
    def __init__(self, cache: EgressCache,
                 policies: tuple[str, ...] = ONLINE_POLICIES,
                 window: int = 512, hysteresis: float = 0.05,
                 auditor: Optional[WindowedAuditor] = None,
                 audit_every_window: bool = False, metrics=None):
        assert window >= 1 and hysteresis >= 0.0
        self.cache = cache
        self.window = int(window)
        self.hysteresis = float(hysteresis)
        self.panel = ShadowPanel(cache.capacity, policies)
        self.auditor = auditor
        self.audit_every_window = audit_every_window
        self.metrics = metrics
        self.swaps: list[SwapEvent] = []
        self._mark = self.panel.dollars()   # shadow $ at window start
        self._since = 0
        cache.add_listener(self._on_event)

    # ------------------------------------------------------------------
    def _on_event(self, ev: AccessEvent) -> None:
        self.panel.on_event(ev)
        if self.auditor is not None:
            self.auditor.on_event(ev)
        self._since += 1
        if self._since >= self.window:
            self._tick(ev.clock)

    def _tick(self, clock: int) -> None:
        now = self.panel.dollars()
        deltas = {p: now[p] - self._mark[p] for p in now}
        self._mark = now
        self._since = 0
        if self.metrics is not None:
            for p, d in deltas.items():
                self.metrics.observe(f"governor.window_dollars.{p}", d,
                                     step=clock)
        incumbent = self.cache.policy
        best = min(deltas, key=lambda p: deltas[p])
        if (best != incumbent and incumbent in deltas
                and deltas[best] < (1.0 - self.hysteresis) * deltas[incumbent]):
            self.cache.set_policy(best)
            self.swaps.append(SwapEvent(clock, incumbent, best, deltas))
            if self.metrics is not None:
                self.metrics.inc("governor.swaps")
        if self.auditor is not None and self.audit_every_window:
            self.auditor.audit()

    # ------------------------------------------------------------------
    def audit(self):
        """Bracket OPT-dollars on the auditor's current window (or None)."""
        return self.auditor.audit() if self.auditor is not None else None

    def snapshot(self) -> dict:
        return dict(
            policy=self.cache.policy,
            swaps=[dataclasses.asdict(s) for s in self.swaps],
            shadow=self.panel.snapshot(),
            live_dollars=self.cache.meter.dollars,
            window=self.window, hysteresis=self.hysteresis,
        )
