"""Windowed exact audit: a live dollar-regret estimate over recent traffic.

Keeps a ring buffer of the last `window` accesses (key, bytes, access-time
miss cost, hit/miss) fed by the live cache's `AccessEvent` stream, and on
demand brackets OPT-dollars on that window with the paper's offline
reference: `exact_opt_uniform_sweep` when the window's sizes are uniform
(one warm-started parametric SSP run answers the whole budget grid,
DESIGN.md §5.2), the cost-FOO LP bracket otherwise. Observed dollars are
the sum of the window's miss costs — exactly what the live cache billed
for those accesses, at the prices in effect when they happened.

The resulting regret series is the governor's "are we leaving dollars on
the table RIGHT NOW" signal, published to the metrics registry.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import Trace, cost_foo, exact_opt_uniform_sweep
from repro.egress.cache import AccessEvent

__all__ = ["Watermark", "WindowAudit", "WindowedAuditor"]


class Watermark:
    """Event-time watermark with a bounded-skew guarantee.

    Tracks the maximum event time seen; the watermark trails it by
    `max_skew`, so any event at or after the watermark may still arrive.
    `advance(t)` ingests one event time, asserts its lateness stays within
    the bound (a violation means the clock-skew model is broken, not that
    an event is merely late), and returns the new watermark. Shared by the
    fleet nodes' tumbling windows (`repro.fleet.node`) and by
    `WindowedAuditor`'s out-of-order tolerance below.
    """

    __slots__ = ("max_skew", "max_time", "events", "late")

    def __init__(self, max_skew: float = 0.0):
        assert max_skew >= 0.0, max_skew
        self.max_skew = float(max_skew)
        self.max_time = float("-inf")
        self.events = 0
        self.late = 0          # events that arrived behind max_time

    @property
    def value(self) -> float:
        """Current watermark: no event older than this will be accepted."""
        return self.max_time - self.max_skew

    def advance(self, event_time: float) -> float:
        t = float(event_time)
        self.events += 1
        if t >= self.max_time:
            self.max_time = t
        else:
            self.late += 1
            if self.max_time - t > self.max_skew:
                raise ValueError(
                    f"event time {t} is {self.max_time - t:.6g} behind the "
                    f"stream maximum {self.max_time}; bounded skew is "
                    f"{self.max_skew:.6g}")
        return self.value


@dataclasses.dataclass
class WindowAudit:
    requests: int
    observed_dollars: float      # what the live cache billed on this window
    opt_dollars_lower: float     # exact (uniform) or cost-FOO lower bound
    opt_dollars_upper: float
    dollar_regret: float         # vs the lower bound (conservative)
    uniform: bool
    opt_by_budget: Optional[dict[int, float]] = None  # uniform + grid only
    audit_seconds: float = 0.0   # wall time of the exact solve itself

    def summary(self) -> str:
        return (f"[window audit] T={self.requests} "
                f"$={self.observed_dollars:.6f} "
                f"OPT in [{self.opt_dollars_lower:.6f}, "
                f"{self.opt_dollars_upper:.6f}] "
                f"regret={self.dollar_regret:.3f}")


class WindowedAuditor:
    """Ring buffer + on-demand exact bracket of OPT-dollars on the window.

    Events are buffered in *event-time* order, not arrival order: a late
    event (skewed delivery from a fleet peer, an out-of-order replay) is
    insorted into its true position so the audit replays the trace the
    accesses actually formed. Lateness is bounded by the shared `Watermark`
    helper (`max_skew`, default: the window length in event-time units) —
    an event older than that is a broken clock model and raises.
    """

    def __init__(self, capacity_bytes: float, window: int = 2048,
                 budget_grid=None, metrics=None,
                 series_name: str = "online.window_regret",
                 max_skew: Optional[float] = None,
                 foo_epoch_len: Optional[int] = None,
                 foo_policies: Optional[tuple[str, ...]] = None):
        self.capacity = float(capacity_bytes)
        self.window = int(window)
        self.budget_grid = (None if budget_grid is None
                            else np.asarray(budget_grid, np.int64))
        self.metrics = metrics
        self.series_name = series_name
        # variable-size audit path (DESIGN.md §4): epoch decomposition +
        # segment-tree rounding keep the cost-FOO bracket inside a window
        # interval even at large `window`; `foo_epoch_len=None` lets
        # cost_foo pick (monolithic up to 25k requests)
        self.foo_epoch_len = foo_epoch_len
        self.foo_policies = foo_policies
        self.watermark = Watermark(float(self.window)
                                   if max_skew is None else max_skew)
        # sorted by (event_time, arrival seq): (t, seq, key, nbytes, mc, hit)
        self._buf: list[tuple] = []
        self._seen = 0
        self.audits = 0

    def on_event(self, ev: AccessEvent) -> None:
        self.watermark.advance(ev.event_time)   # asserts bounded skew
        self._seen += 1
        entry = (ev.event_time, self._seen, ev.key, ev.nbytes,
                 ev.miss_cost, ev.hit)
        if not self._buf or entry >= self._buf[-1]:
            self._buf.append(entry)             # in-order fast path
        else:
            bisect.insort(self._buf, entry)     # late: fold into position
        if len(self._buf) > self.window:
            del self._buf[0]

    def __len__(self) -> int:
        return len(self._buf)

    def audit(self) -> Optional[WindowAudit]:
        """Bracket OPT-dollars on the buffered window; None if empty."""
        if not self._buf:
            return None
        buf = list(self._buf)
        uniq: dict[str, int] = {}
        ids = np.empty(len(buf), np.int32)
        sizes: list[float] = []
        costs: list[float] = []
        observed = 0.0
        for t, (_et, _seq, key, nbytes, mc, hit) in enumerate(buf):
            i = uniq.get(key)
            if i is None:
                i = uniq[key] = len(sizes)
                sizes.append(float(nbytes))
                costs.append(float(mc))
            else:
                costs[i] = float(mc)   # latest access-time price wins
            ids[t] = i
            if not hit:
                observed += mc
        sizes_arr = np.asarray(sizes)
        costs_arr = np.asarray(costs)
        uniform = len(set(sizes_arr.tolist())) == 1
        opt_by_budget = None
        t_solve = time.perf_counter()
        if uniform:
            B = max(1, int(self.capacity // sizes_arr[0]))
            grid = (np.unique(np.append(self.budget_grid, B))
                    if self.budget_grid is not None
                    else np.asarray([B], np.int64))
            sweep = exact_opt_uniform_sweep(ids, costs_arr, grid)
            opt_by_budget = {int(b): float(d)
                             for b, d in zip(sweep.budgets, sweep.dollars)}
            lower = upper = opt_by_budget[int(B)]
            if self.metrics is not None and sweep.profile:
                # solver profiling (DESIGN.md §9): where audit time goes
                self.metrics.inc("solver.sweep.runs")
                self.metrics.inc("solver.sweep.dijkstra_calls",
                                 sweep.profile["dijkstra_calls"])
                self.metrics.inc("solver.sweep.augmentations",
                                 sweep.profile["augmentations"])
                self.metrics.inc("solver.sweep.budgets_answered",
                                 sweep.profile["budgets_answered"])
        else:
            tr = Trace(ids=ids, sizes=sizes_arr, name="window_audit")
            kwargs = {}
            if self.foo_policies is not None:
                kwargs["policies"] = self.foo_policies
            r = cost_foo(tr, costs_arr, self.capacity,
                         epoch_len=self.foo_epoch_len, **kwargs)
            lower, upper = r.lower, r.upper
            if self.metrics is not None and r.profile:
                # solver profiling (DESIGN.md §9): how the bracket was made
                self.metrics.inc("solver.costfoo.runs")
                self.metrics.inc("solver.costfoo.epochs",
                                 r.profile.get("epochs", 1))
                self.metrics.inc("solver.costfoo.crossing_intervals",
                                 r.profile.get("crossing_intervals", 0))
        audit_seconds = time.perf_counter() - t_solve
        # observed >= lower mathematically; clip float jitter at exactly-OPT
        reg = max(0.0, (observed - lower) / max(lower, 1e-12))
        self.audits += 1
        if self.metrics is not None:
            self.metrics.observe(self.series_name, reg, step=self._seen)
            self.metrics.observe("online.audit_seconds", audit_seconds,
                                 step=self._seen)
            oh = getattr(self.metrics, "observe_hist", None)
            if oh is not None:   # windowed-regret histogram (DESIGN.md §9)
                oh(self.series_name + "_hist", reg,
                   bounds=[0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0])
        return WindowAudit(requests=len(buf), observed_dollars=observed,
                           opt_dollars_lower=lower, opt_dollars_upper=upper,
                           dollar_regret=reg, uniform=uniform,
                           opt_by_budget=opt_by_budget,
                           audit_seconds=audit_seconds)
