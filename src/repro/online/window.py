"""Windowed exact audit: a live dollar-regret estimate over recent traffic.

Keeps a ring buffer of the last `window` accesses (key, bytes, access-time
miss cost, hit/miss) fed by the live cache's `AccessEvent` stream, and on
demand brackets OPT-dollars on that window with the paper's offline
reference: `exact_opt_uniform_sweep` when the window's sizes are uniform
(one warm-started parametric SSP run answers the whole budget grid,
DESIGN.md §5.2), the cost-FOO LP bracket otherwise. Observed dollars are
the sum of the window's miss costs — exactly what the live cache billed
for those accesses, at the prices in effect when they happened.

The resulting regret series is the governor's "are we leaving dollars on
the table RIGHT NOW" signal, published to the metrics registry.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import numpy as np

from repro.core import Trace, cost_foo, exact_opt_uniform_sweep
from repro.egress.cache import AccessEvent

__all__ = ["WindowAudit", "WindowedAuditor"]


@dataclasses.dataclass
class WindowAudit:
    requests: int
    observed_dollars: float      # what the live cache billed on this window
    opt_dollars_lower: float     # exact (uniform) or cost-FOO lower bound
    opt_dollars_upper: float
    dollar_regret: float         # vs the lower bound (conservative)
    uniform: bool
    opt_by_budget: Optional[dict[int, float]] = None  # uniform + grid only

    def summary(self) -> str:
        return (f"[window audit] T={self.requests} "
                f"$={self.observed_dollars:.6f} "
                f"OPT in [{self.opt_dollars_lower:.6f}, "
                f"{self.opt_dollars_upper:.6f}] "
                f"regret={self.dollar_regret:.3f}")


class WindowedAuditor:
    """Ring buffer + on-demand exact bracket of OPT-dollars on the window."""

    def __init__(self, capacity_bytes: float, window: int = 2048,
                 budget_grid=None, metrics=None,
                 series_name: str = "online.window_regret"):
        self.capacity = float(capacity_bytes)
        self.window = int(window)
        self.budget_grid = (None if budget_grid is None
                            else np.asarray(budget_grid, np.int64))
        self.metrics = metrics
        self.series_name = series_name
        self._buf: collections.deque = collections.deque(maxlen=self.window)
        self._seen = 0
        self.audits = 0

    def on_event(self, ev: AccessEvent) -> None:
        self._buf.append((ev.key, ev.nbytes, ev.miss_cost, ev.hit))
        self._seen += 1

    def __len__(self) -> int:
        return len(self._buf)

    def audit(self) -> Optional[WindowAudit]:
        """Bracket OPT-dollars on the buffered window; None if empty."""
        if not self._buf:
            return None
        buf = list(self._buf)
        uniq: dict[str, int] = {}
        ids = np.empty(len(buf), np.int32)
        sizes: list[float] = []
        costs: list[float] = []
        observed = 0.0
        for t, (key, nbytes, mc, hit) in enumerate(buf):
            i = uniq.get(key)
            if i is None:
                i = uniq[key] = len(sizes)
                sizes.append(float(nbytes))
                costs.append(float(mc))
            else:
                costs[i] = float(mc)   # latest access-time price wins
            ids[t] = i
            if not hit:
                observed += mc
        sizes_arr = np.asarray(sizes)
        costs_arr = np.asarray(costs)
        uniform = len(set(sizes_arr.tolist())) == 1
        opt_by_budget = None
        if uniform:
            B = max(1, int(self.capacity // sizes_arr[0]))
            grid = (np.unique(np.append(self.budget_grid, B))
                    if self.budget_grid is not None
                    else np.asarray([B], np.int64))
            sweep = exact_opt_uniform_sweep(ids, costs_arr, grid)
            opt_by_budget = {int(b): float(d)
                             for b, d in zip(sweep.budgets, sweep.dollars)}
            lower = upper = opt_by_budget[int(B)]
            if self.metrics is not None and sweep.profile:
                # solver profiling (DESIGN.md §9): where audit time goes
                self.metrics.inc("solver.sweep.runs")
                self.metrics.inc("solver.sweep.dijkstra_calls",
                                 sweep.profile["dijkstra_calls"])
                self.metrics.inc("solver.sweep.augmentations",
                                 sweep.profile["augmentations"])
                self.metrics.inc("solver.sweep.budgets_answered",
                                 sweep.profile["budgets_answered"])
        else:
            tr = Trace(ids=ids, sizes=sizes_arr, name="window_audit")
            r = cost_foo(tr, costs_arr, self.capacity)
            lower, upper = r.lower, r.upper
        # observed >= lower mathematically; clip float jitter at exactly-OPT
        reg = max(0.0, (observed - lower) / max(lower, 1e-12))
        self.audits += 1
        if self.metrics is not None:
            self.metrics.observe(self.series_name, reg, step=self._seen)
            oh = getattr(self.metrics, "observe_hist", None)
            if oh is not None:   # windowed-regret histogram (DESIGN.md §9)
                oh(self.series_name + "_hist", reg,
                   bounds=[0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0])
        return WindowAudit(requests=len(buf), observed_dollars=observed,
                           opt_dollars_lower=lower, opt_dollars_upper=upper,
                           dollar_regret=reg, uniform=uniform,
                           opt_by_budget=opt_by_budget)
