"""Synthetic regime-shift scenarios for evaluating the dollar-governor.

The canonical scenario flips the price vector across s* mid-trace while
the access pattern stays stationary (built from the same ingredients as
`core/trace.py`'s stand-ins: a hot set of small objects, a round-robin
working set of big objects with slow rotation, and periodic one-hit scan
bursts — the wiki-CDN pollution motif):

  * phase A, fee-dominated (s* >> all sizes): every miss costs ~f, so
    dollars = f x misses and the best policy maximizes hits — recency
    (LRU) wins, because scan bursts are cheap to re-fetch but deadly to
    frequency-blind retention of the big working set.
  * phase B, egress-dominated (s* << all sizes): a miss costs ~s*e, so
    the bill is byte-weighted and the best policy protects the big
    objects from scan bursts — GDSF wins (scan keys never outrank a
    reused big's freq x density score), while LRU re-fetches ~the whole
    big working set after every burst.

No fixed policy wins both phases; a governor that tracks the windowed
shadow panel should. `run_fixed` / `run_governed` replay the scenario on
fresh stores so realized dollars are comparable in hindsight.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.pricing import PriceVector
from repro.egress.cache import ONLINE_POLICIES, EgressCache
from repro.egress.store import ObjectStore
from .governor import DollarGovernor
from .window import WindowedAuditor

__all__ = ["RegimeShiftScenario", "regime_shift_scenario", "run_fixed",
           "run_governed", "FEE_HEAVY", "EGRESS_HEAVY"]

# s* = f/e = 1e7 B: every object below is fee-dominated
FEE_HEAVY = PriceVector("fee_heavy", get_fee=1e-5, egress_per_byte=1e-12)
# s* = 10 B: every object is egress-dominated
EGRESS_HEAVY = PriceVector("egress_heavy", get_fee=1e-9, egress_per_byte=1e-10)


@dataclasses.dataclass(frozen=True)
class RegimeShiftScenario:
    keys: list                 # request stream (object keys)
    sizes: dict                # key -> bytes
    flip_at: int               # request index where the price flips
    price_a: PriceVector
    price_b: PriceVector
    capacity_bytes: float

    @property
    def num_requests(self) -> int:
        return len(self.keys)

    def make_store(self) -> ObjectStore:
        store = ObjectStore(self.price_a)
        for k, s in self.sizes.items():
            store.put(k, bytes(s))
        return store


def regime_shift_scenario(n_phase: int = 3000, seed: int = 0,
                          small_bytes: int = 1024, big_bytes: int = 1 << 16,
                          n_hot_small: int = 30, hot_drift: int = 15,
                          n_big_active: int = 6,
                          rotate_every: int = 600, block: int = 450,
                          burst_len: int = 200) -> RegimeShiftScenario:
    """Two equal phases of the stationary mix; price flips at `n_phase`.

    Each `block` of requests is a steady segment (hot smalls and active
    bigs alternating) followed by `burst_len` fresh one-hit scan keys.
    Every `rotate_every` big accesses the oldest active big retires and a
    fresh one enters; `hot_drift` > 0 slides the hot-small window by that
    many objects per block (recency-driven churn that frequency-anchored
    retention tracks late).
    """
    rng = np.random.default_rng(seed)
    sizes: dict = {}
    hot_base = 0
    active = list(range(n_big_active))
    next_big = n_big_active
    big_accesses = 0
    big_rr = 0
    scan_id = 0
    keys: list = []
    total = 2 * n_phase
    while len(keys) < total:
        steady = block - burst_len
        for j in range(steady):
            if len(keys) >= total:
                break
            if j % 2 == 0:
                h = hot_base + int(rng.integers(n_hot_small))
                keys.append(f"hot{h}")
                sizes.setdefault(f"hot{h}", small_bytes)
            else:
                b = active[big_rr % n_big_active]
                big_rr += 1
                big_accesses += 1
                keys.append(f"big{b}")
                sizes.setdefault(f"big{b}", big_bytes)
                if rotate_every and big_accesses % rotate_every == 0:
                    active.pop(0)
                    active.append(next_big)
                    next_big += 1
        for _ in range(burst_len):
            if len(keys) >= total:
                break
            keys.append(f"scan{scan_id}")
            sizes[f"scan{scan_id}"] = small_bytes
            scan_id += 1
        hot_base += hot_drift
    capacity = n_big_active * big_bytes + int(1.2 * n_hot_small * small_bytes)
    return RegimeShiftScenario(keys=keys, sizes=sizes, flip_at=n_phase,
                               price_a=FEE_HEAVY, price_b=EGRESS_HEAVY,
                               capacity_bytes=float(capacity))


def _replay(sc: RegimeShiftScenario, cache: EgressCache,
            store: ObjectStore) -> None:
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        cache.get(key)


def run_fixed(sc: RegimeShiftScenario, policy: str) -> dict:
    """Realized dollars of one fixed policy over the full scenario."""
    store = sc.make_store()
    cache = EgressCache(store, sc.capacity_bytes, policy,
                        consumer=f"fixed_{policy}")
    _replay(sc, cache, store)
    return dict(policy=policy, dollars=cache.meter.dollars,
                hits=cache.hits, misses=cache.misses,
                hit_rate=cache.hit_rate)


def run_governed(sc: RegimeShiftScenario, start_policy: str = "lfu",
                 policies: tuple = ONLINE_POLICIES, window: int = 400,
                 hysteresis: float = 0.1,
                 auditor_window: Optional[int] = None,
                 metrics=None) -> tuple[dict, DollarGovernor]:
    """Realized dollars under the governor (fresh store, same scenario)."""
    store = sc.make_store()
    cache = EgressCache(store, sc.capacity_bytes, start_policy,
                        consumer="governed", metrics=metrics)
    auditor = (WindowedAuditor(sc.capacity_bytes, window=auditor_window,
                               metrics=metrics)
               if auditor_window else None)
    gov = DollarGovernor(cache, policies=policies, window=window,
                         hysteresis=hysteresis, auditor=auditor,
                         metrics=metrics)
    _replay(sc, cache, store)
    result = dict(policy="governed", dollars=cache.meter.dollars,
                  hits=cache.hits, misses=cache.misses,
                  hit_rate=cache.hit_rate,
                  final_policy=cache.policy,
                  swaps=[(s.clock, s.old_policy, s.new_policy)
                         for s in gov.swaps])
    return result, gov
