"""s*-aware admission: the crossover (eq. 3) as a live bypass/keep rule.

The crossover s* = f/e splits the object universe by what a miss costs:

  * s <= s*  — fee-dominated. The full GET fee is saved by any future hit
    and the object occupies almost nothing; always worth keeping.
  * s > s*   — egress-dominated. The saving scales with bytes, but so does
    the occupancy; a giant single-touch object (the wiki-CDN one-hit-wonder
    tail, DESIGN.md §7) evicts an entire working set for nothing. Such
    objects are only admitted on REUSE (second touch within the frequency
    horizon), and never when one object would consume more than
    `large_object_frac` of the cache.

The price is read through a callable so a mid-stream repricing
(`ObjectStore.set_price`) moves the admission line in real time.
"""
from __future__ import annotations

from typing import Callable, Union

from repro.core.pricing import PriceVector
from repro.egress.store import ObjectStore

__all__ = ["SStarAdmission"]


class SStarAdmission:
    """Plugs into `EgressCache(admission=...)` (see AdmissionController)."""

    def __init__(self, price: Union[PriceVector, Callable[[], PriceVector],
                                    ObjectStore],
                 capacity_bytes: float, large_object_frac: float = 0.5,
                 probation_above_sstar: bool = True):
        if isinstance(price, ObjectStore):
            self._price = lambda: price.price
        elif isinstance(price, PriceVector):
            self._price = lambda: price
        else:
            self._price = price
        self.capacity = float(capacity_bytes)
        self.large_object_frac = float(large_object_frac)
        self.probation_above_sstar = probation_above_sstar
        self.admitted = 0
        self.bypassed = 0

    @property
    def crossover_bytes(self) -> float:
        return self._price().crossover_bytes

    def admit(self, key: str, nbytes: int, freq: int) -> bool:
        decision = self._decide(nbytes, freq)
        if decision:
            self.admitted += 1
        else:
            self.bypassed += 1
        return decision

    def _decide(self, nbytes: int, freq: int) -> bool:
        if nbytes <= self.crossover_bytes:
            return True                       # fee-dominated: always keep
        if nbytes > self.large_object_frac * self.capacity:
            return False                      # would displace the working set
        if self.probation_above_sstar:
            return freq >= 2                  # egress-dominated: keep on reuse
        return True
