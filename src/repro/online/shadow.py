"""Shadow-policy panel: counterfactual dollars for every online policy.

Each `ShadowCache` is a metadata-only replica of `EgressCache`'s priority
machinery (same LRU/LFU/GDS/GDSF formulas as `core/policies.py`, same
lazy-deletion heap and last-touch tiebreak) that holds sizes instead of
bytes. The panel subscribes to the live cache's `AccessEvent` stream and
replays every request against all shadows simultaneously, accruing the
dollars each policy WOULD have billed — without ever touching the
`ObjectStore`, so shadowing bills $0 of extra egress (asserted via
per-consumer meters in tests).

Miss costs come from the event (`AccessEvent.miss_cost`, priced at access
time), so a mid-stream price flip (`ObjectStore.set_price`) is reflected
in every shadow's counterfactual bill exactly as in the live one.
"""
from __future__ import annotations

import heapq

from repro.egress.cache import ONLINE_POLICIES, AccessEvent

__all__ = ["ShadowCache", "ShadowPanel"]


class ShadowCache:
    """Metadata-only cache simulation: keys, sizes, priorities — no bytes."""

    def __init__(self, policy: str, capacity_bytes: float):
        assert policy in ONLINE_POLICIES, policy
        self.policy = policy
        self.capacity = float(capacity_bytes)
        self.used = 0.0
        self._sizes: dict[str, int] = {}          # resident keys -> bytes
        self._prio: dict[str, tuple[float, int]] = {}
        self._heap: list[tuple[float, int, str]] = []
        self._freq: dict[str, int] = {}
        self._inflation = 0.0
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.dollars = 0.0       # counterfactual: what this policy would bill

    def _priority(self, key: str, nbytes: int, miss_cost: float) -> float:
        dens = miss_cost / max(nbytes, 1)
        if self.policy == "lru":
            return float(self._clock)
        if self.policy == "lfu":
            return float(self._freq[key])
        if self.policy == "gds":
            return self._inflation + dens
        return self._inflation + self._freq[key] * dens  # gdsf

    def _touch(self, key: str, nbytes: int, miss_cost: float):
        pr = self._priority(key, nbytes, miss_cost)
        self._prio[key] = (pr, self._clock)
        heapq.heappush(self._heap, (pr, self._clock, key))

    def _evict_until_fits(self, need: float):
        while self.used + need > self.capacity and self._prio:
            pr, tt, key = heapq.heappop(self._heap)
            if self._prio.get(key) != (pr, tt):
                continue
            del self._prio[key]
            self.used -= self._sizes.pop(key)
            if self.policy in ("gds", "gdsf"):
                self._inflation = pr

    def access(self, key: str, nbytes: int, miss_cost: float) -> bool:
        """Replay one request; returns True on a (counterfactual) hit."""
        self._clock += 1
        freq = self._freq.get(key, 0) + 1
        self._freq[key] = freq
        if key in self._sizes:
            self.hits += 1
            # hit fast path: LRU/LFU priorities are just the clock / count —
            # skip the policy dispatch chain and the density division that
            # `_priority` would redo per hit (bench_policy_throughput
            # asserts the panel's ns/access against the generic path)
            policy = self.policy
            if policy == "lru":
                pr = float(self._clock)
            elif policy == "lfu":
                pr = float(freq)
            else:
                pr = self._priority(key, nbytes, miss_cost)
            self._prio[key] = (pr, self._clock)
            heapq.heappush(self._heap, (pr, self._clock, key))
            return True
        self.misses += 1
        self.dollars += miss_cost
        if nbytes <= self.capacity:
            self._evict_until_fits(nbytes)
            self._sizes[key] = nbytes
            self.used += nbytes
            self._touch(key, nbytes, miss_cost)
        return False

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ShadowPanel:
    """One shadow cache per policy, all driven by the same event stream."""

    def __init__(self, capacity_bytes: float,
                 policies: tuple[str, ...] = ONLINE_POLICIES):
        self.shadows = {p: ShadowCache(p, capacity_bytes) for p in policies}

    def on_event(self, ev: AccessEvent) -> None:
        for sh in self.shadows.values():
            sh.access(ev.key, ev.nbytes, ev.miss_cost)

    @property
    def policies(self) -> tuple[str, ...]:
        return tuple(self.shadows)

    def dollars(self) -> dict[str, float]:
        return {p: sh.dollars for p, sh in self.shadows.items()}

    def snapshot(self) -> dict:
        return {p: dict(dollars=sh.dollars, hits=sh.hits, misses=sh.misses,
                        hit_rate=sh.hit_rate, used=sh.used)
                for p, sh in self.shadows.items()}
