# Online dollar-governance over the egress stack (DESIGN.md §8):
#   metrics   — back-compat shim; the registry lives in repro.obs.metrics (§9)
#   shadow    — metadata-only shadow panel: counterfactual $ per policy, $0 egress
#   window    — ring-buffered exact audit: live OPT-dollar bracket + regret
#   admission — s*-aware bypass/keep rule (eq. 3 as an admission controller)
#   governor  — hysteresis policy hot-swap driven by windowed shadow dollars
from .metrics import MetricsRegistry
from .shadow import ShadowCache, ShadowPanel
from .window import Watermark, WindowAudit, WindowedAuditor
from .admission import SStarAdmission
from .governor import DollarGovernor, SwapEvent

__all__ = [
    "MetricsRegistry", "ShadowCache", "ShadowPanel", "Watermark",
    "WindowAudit", "WindowedAuditor", "SStarAdmission", "DollarGovernor",
    "SwapEvent",
]
