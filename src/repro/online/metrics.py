"""Metrics registry for the online governance layer (DESIGN.md §8).

A single process-local registry of counters, gauges, and time series that
`ObjectStore`, `EgressCache`, `ServeEngine`, and the dollar-governor all
publish through. Publishers hold it duck-typed (anything with `.inc` /
`.set_gauge` / `.observe`), so the egress layer never imports this module
— `repro.online` sits strictly above `repro.egress`.

Export is JSON (`to_json` / `write_json`): the artifact consumed by
`examples/policy_audit.py` and `benchmarks/bench_governor.py`.
"""
from __future__ import annotations

import json
import pathlib
import threading
from typing import Optional

__all__ = ["MetricsRegistry"]


class MetricsRegistry:
    """Counters (monotone), gauges (last value), series ((step, value) lists)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.series: dict[str, list[tuple[int, float]]] = {}
        self._step = 0

    # ---- publishing -------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float,
                step: Optional[int] = None) -> None:
        """Append to a time series; `step` defaults to an internal tick."""
        with self._lock:
            if step is None:
                self._step += 1
                step = self._step
            self.series.setdefault(name, []).append((int(step), float(value)))

    # ---- reading / export -------------------------------------------------
    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def latest(self, name: str) -> Optional[float]:
        s = self.series.get(name)
        return s[-1][1] if s else None

    def snapshot(self) -> dict:
        with self._lock:
            return dict(
                counters=dict(self.counters),
                gauges=dict(self.gauges),
                series={k: [list(p) for p in v]
                        for k, v in self.series.items()},
            )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def write_json(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path
