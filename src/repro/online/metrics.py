"""Back-compat shim: `MetricsRegistry` moved to `repro.obs.metrics`.

The registry was promoted into the observability layer (DESIGN.md §9)
when it grew histograms and Prometheus exposition; import it from
`repro.obs` in new code. This module keeps `repro.online.metrics` (and
`from repro.online import MetricsRegistry`) working unchanged.
"""
from repro.obs.metrics import (Histogram, MetricsRegistry,  # noqa: F401
                               log_bounds, sstar_bounds)

__all__ = ["MetricsRegistry", "Histogram", "log_bounds", "sstar_bounds"]
