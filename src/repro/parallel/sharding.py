"""Logical-axis -> mesh-axis sharding rules (FSDP + TP, divisibility-aware).

Parameters declare logical axes (models/common.ParamDef); this module maps
them onto the production mesh:

  embed           -> FSDP over ("pod", "data")   (ZeRO-3 style)
  heads/kv_heads/
  mlp/vocab       -> tensor-parallel over "model"
  expert          -> replicated in the baseline; "model" under
                     expert-parallelism (--moe-ep, evaluated in §Perf)

Every rule is divisibility-checked against the actual dimension: if a dim
does not divide by the mesh-axes product the rule degrades gracefully
(drop trailing axes, then give up to None) instead of relying on GSPMD
padding. kv_heads smaller than the TP width therefore replicate, and the
KV-cache *sequence* axis picks up the TP sharding instead (flash-decode
style) — see kv_cache_spec.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "make_rules", "params_sharding", "batch_spec",
           "kv_cache_sharding", "mesh_axis_size"]


def mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


class ShardingRules:
    def __init__(self, mesh: Mesh, rules: dict[str, Any], dp_axes):
        self.mesh = mesh
        self.rules = rules
        self.dp_axes = dp_axes  # axes the batch is sharded over

    def _fit(self, dim: int, axes) -> Optional[Any]:
        """Return axes (possibly shortened) that evenly divide dim.

        Axis tuples are ordered smallest-first (("pod","data")): when the
        full product doesn't divide, drop the *leading* (small) axes so the
        fallback keeps the widest parallelism (e.g. 16 rows on a 2x16
        ("pod","data") axis shard 16-way over "data", not 2-way over "pod").
        """
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(axes)
        while axes:
            if dim % mesh_axis_size(self.mesh, axes) == 0:
                return axes if len(axes) > 1 else axes[0]
            axes = axes[1:]
        return None

    def spec_for(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
        used: set[str] = set()
        out = []
        for dim, name in zip(shape, logical):
            axes = self.rules.get(name) if name else None
            axes = self._fit(dim, axes)
            # a mesh axis may appear only once per spec
            if axes is not None:
                flat = (axes,) if isinstance(axes, str) else tuple(axes)
                if any(a in used for a in flat):
                    axes = None
                else:
                    used.update(flat)
            out.append(axes)
        return P(*out)


def make_rules(mesh: Mesh, *, moe_ep: bool = False) -> ShardingRules:
    """Default FSDP+TP rules for this mesh (single- or multi-pod)."""
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    rules = {
        "embed": fsdp,
        "vocab": tp,
        "heads": tp,
        "kv_heads": tp,
        "mlp": tp,
        "expert": tp if moe_ep else None,
    }
    if moe_ep:
        # expert-parallel: experts over "model"; expert matrices FSDP only
        rules = dict(rules, expert=tp, mlp=fsdp)
    return ShardingRules(mesh, rules, dp_axes=fsdp)


def params_sharding(rules: ShardingRules, abstract_params, axes_tree):
    """NamedSharding pytree matching the abstract parameter tree."""
    def one(p, axes):
        return NamedSharding(rules.mesh, rules.spec_for(p.shape, axes))
    return jax.tree.map(one, abstract_params, axes_tree)


def batch_spec(rules: ShardingRules, abstract_batch):
    """Input batch: leading (batch) dim over the DP axes when divisible."""
    def one(x):
        b = x.shape[0]
        axes = rules._fit(b, rules.dp_axes)
        return NamedSharding(rules.mesh,
                             P(*([axes] + [None] * (x.ndim - 1))))
    return jax.tree.map(one, abstract_batch)


def kv_cache_sharding(rules: ShardingRules, abstract_caches):
    """Decode caches. 4D KV tensors (B, S, G, hd): batch over DP when
    divisible; G over TP when divisible, otherwise the *sequence* axis picks
    up TP (flash-decode; GSPMD inserts the softmax combine collectives).
    Low-rank recurrent states (B, ...): batch over DP, rest replicated/TP.
    """
    mesh = rules.mesh
    tp = rules.rules.get("heads")

    def one(x):
        bdim = x.shape[0]
        baxes = rules._fit(bdim, rules.dp_axes)
        if x.ndim == 4:
            B, S, G, hd = x.shape
            gaxes = rules._fit(G, tp)
            if gaxes is None and S < 8192:
                # small (window-capped) caches: replication beats the
                # resharding traffic of a TP-sharded shift cache (§Perf 3b)
                return NamedSharding(mesh, P(baxes, None, None, None))
            if gaxes is None:
                saxes = rules._fit(S, tp)
                if baxes is None and saxes is not None:
                    # long-context bs=1: spread the sequence over everything
                    all_axes = rules._fit(S, tuple(
                        a for a in (*((rules.dp_axes,) if isinstance(
                            rules.dp_axes, str) else rules.dp_axes), tp)
                        if a is not None))
                    return NamedSharding(mesh, P(None, all_axes, None, None))
                return NamedSharding(mesh, P(baxes, saxes, None, None))
            return NamedSharding(mesh, P(baxes, None, gaxes, None))
        if x.ndim == 2:   # (B, d) recurrent state
            return NamedSharding(mesh, P(baxes, None))
        if x.ndim == 3:   # (B, w, d) conv state or (B, H, hd)
            return NamedSharding(mesh, P(baxes, None, None))
        if x.ndim == 4 + 0:
            pass
        # (B, H, hd, hd) mLSTM matrix state etc.
        return NamedSharding(mesh,
                             P(*([baxes] + [None] * (x.ndim - 1))))
    return jax.tree.map(one, abstract_caches)