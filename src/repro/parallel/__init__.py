# Sharding layer: logical-axis -> mesh-axis rules (FSDP + TP) shared by
# train and serve step assembly.
from .sharding import (ShardingRules, batch_spec, kv_cache_sharding,
                       make_rules, mesh_axis_size, params_sharding)

__all__ = ["ShardingRules", "make_rules", "params_sharding", "batch_spec",
           "kv_cache_sharding", "mesh_axis_size"]
