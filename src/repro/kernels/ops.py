"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel body in Python); on a real TPU pass
interpret=False (or rely on the default backend detection below) to lower
to Mosaic. The pure-jnp oracles in ref.py define the semantics either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .evict_argmin import evict_argmin_pallas
from .interval_occupancy import (interval_occupancy_pallas,
                                 occupancy_feasible_pallas)
from .next_use import next_use_pallas

__all__ = ["next_use", "evict_argmin", "interval_occupancy",
           "occupancy_feasible", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def next_use(ids: jax.Array, num_objects: int, *, block_t: int = 1024,
             use_pallas: bool | None = None) -> jax.Array:
    """next(t) per request (T where the object never recurs)."""
    if use_pallas is None:
        use_pallas = True
    if use_pallas:
        return next_use_pallas(ids, num_objects, block_t=block_t,
                               interpret=not on_tpu())
    return ref.next_use_ref(ids, num_objects)


def evict_argmin(scores: jax.Array, touch: jax.Array, mask: jax.Array, *,
                 block_n: int = 2048, use_pallas: bool | None = None):
    """Victim selection: lexicographic argmin of (score, touch) where mask."""
    if use_pallas is None:
        use_pallas = True
    if use_pallas:
        return evict_argmin_pallas(scores, touch, mask, block_n=block_n,
                                   interpret=not on_tpu())
    return ref.evict_argmin_ref(scores, touch, mask)


def interval_occupancy(deltas: jax.Array, *, block_t: int = 2048,
                       use_pallas: bool | None = None) -> jax.Array:
    """Occupancy profile (inclusive prefix sum) of eq. (2)'s LHS."""
    if use_pallas is None:
        use_pallas = True
    if use_pallas:
        return interval_occupancy_pallas(deltas, block_t=block_t,
                                         interpret=not on_tpu())
    return ref.interval_occupancy_ref(deltas)


def occupancy_feasible(deltas: jax.Array, zcap: jax.Array, *,
                       block_t: int = 2048, use_pallas: bool | None = None):
    """Schedule feasibility: (occupancy profile, max excess over zcap).

    The device-resident check of cost-FOO's rounded schedule
    (DESIGN.md §4): deltas are the accepted intervals' range-adds, the
    fused scan carries occupancy + running max(occ - zcap) in SMEM.
    """
    if use_pallas is None:
        use_pallas = True
    if use_pallas:
        return occupancy_feasible_pallas(deltas, zcap, block_t=block_t,
                                         interpret=not on_tpu())
    return ref.occupancy_feasible_ref(deltas, zcap)