# Pallas TPU kernels for the paper's trace-replay hot spots (DESIGN.md §3):
#   next_use            — Belady / interval-construction next(t) pass
#   evict_argmin        — the eviction decision of every priority policy
#   interval_occupancy  — eq. (2) occupancy profile (blocked prefix sum)
#   occupancy_feasible  — fused range-add scan + running-max cap check of
#                         cost-FOO's rounded schedule (DESIGN.md §4)
# Each has a pallas_call implementation, a jit'd wrapper in ops.py and a
# pure-jnp oracle in ref.py; tests sweep shapes/dtypes against the oracle.
from . import ops, ref
from .ops import (evict_argmin, interval_occupancy, next_use,
                  occupancy_feasible)

__all__ = ["ops", "ref", "next_use", "evict_argmin", "interval_occupancy",
           "occupancy_feasible"]
