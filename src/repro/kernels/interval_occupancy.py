"""Pallas TPU kernel: blocked prefix-sum of occupancy deltas (eq. 2 LHS).

Feasibility checking / contention profiling of a retention schedule needs
the occupancy profile occ(p) = sum of sizes of intervals covering serving
instant p. With per-position deltas (+s_i at interval start, -s_i one past
its end) this is a prefix sum over the request timeline — on TPU a
sequential-grid blocked scan: each grid step cumsums its VMEM block and
adds the running total carried in SMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["interval_occupancy_pallas"]


def _kernel(deltas_ref, out_ref, carry_ref, *, block_t: int):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        carry_ref[0] = jnp.float32(0.0)

    block = deltas_ref[...].astype(jnp.float32)
    scanned = jnp.cumsum(block) + carry_ref[0]
    out_ref[...] = scanned
    carry_ref[0] = scanned[-1]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def interval_occupancy_pallas(deltas: jax.Array, block_t: int = 2048,
                              interpret: bool = True) -> jax.Array:
    """Inclusive prefix sum of (T,) float deltas -> (T,) float32 occupancy."""
    T = deltas.shape[0]
    num_blocks = -(-T // block_t)
    Tpad = num_blocks * block_t
    if Tpad != T:
        deltas = jnp.pad(deltas, (0, Tpad - T))
    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_t,), lambda g: (g,))],
        out_specs=pl.BlockSpec((block_t,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((Tpad,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(deltas)
    return out[:T]