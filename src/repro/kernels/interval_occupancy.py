"""Pallas TPU kernels: blocked occupancy scan + feasibility (eq. 2 LHS).

Feasibility checking / contention profiling of a retention schedule needs
the occupancy profile occ(p) = sum of sizes of intervals covering serving
instant p. With per-position deltas (+s_i at interval start, -s_i one past
its end) this is a prefix sum over the request timeline — on TPU a
sequential-grid blocked scan: each grid step cumsums its VMEM block and
adds the running total carried in SMEM scratch.

`occupancy_feasible_pallas` fuses the feasibility verdict into the same
scan: the deltas ARE the range-adds of the rounding pass's accepted
intervals, and the kernel carries a running max of occ - zcap alongside
the prefix-sum carry, so "does the schedule ever exceed the cap" is one
device-resident pass instead of a host round-trip per interval
(DESIGN.md §4; dispatched behind `use_pallas`/`on_tpu()` like
`evict_argmin`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["interval_occupancy_pallas", "occupancy_feasible_pallas"]

_NEG_BIG = -3.4e38

# jax >= 0.5 renamed pltpu.TPUMemorySpace -> pltpu.MemorySpace; the SMEM
# constant exists under both spellings.
_SMEM = getattr(pltpu, "MemorySpace", getattr(pltpu, "TPUMemorySpace", None)).SMEM


def _kernel(deltas_ref, out_ref, carry_ref, *, block_t: int):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        carry_ref[0] = jnp.float32(0.0)

    block = deltas_ref[...].astype(jnp.float32)
    scanned = jnp.cumsum(block) + carry_ref[0]
    out_ref[...] = scanned
    carry_ref[0] = scanned[-1]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def interval_occupancy_pallas(deltas: jax.Array, block_t: int = 2048,
                              interpret: bool = True) -> jax.Array:
    """Inclusive prefix sum of (T,) float deltas -> (T,) float32 occupancy."""
    T = deltas.shape[0]
    num_blocks = -(-T // block_t)
    Tpad = num_blocks * block_t
    if Tpad != T:
        deltas = jnp.pad(deltas, (0, Tpad - T))
    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_t,), lambda g: (g,))],
        out_specs=pl.BlockSpec((block_t,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((Tpad,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(deltas)
    return out[:T]


def _feas_kernel(deltas_ref, zcap_ref, occ_ref, excess_ref, carry_ref, *,
                 num_blocks: int):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        carry_ref[0] = jnp.float32(0.0)       # running occupancy
        carry_ref[1] = jnp.float32(_NEG_BIG)  # running max of occ - zcap

    block = deltas_ref[...].astype(jnp.float32)
    scanned = jnp.cumsum(block) + carry_ref[0]
    occ_ref[...] = scanned
    carry_ref[0] = scanned[-1]
    carry_ref[1] = jnp.maximum(
        carry_ref[1], jnp.max(scanned - zcap_ref[...].astype(jnp.float32)))

    @pl.when(g == num_blocks - 1)
    def _emit():
        excess_ref[0] = carry_ref[1]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def occupancy_feasible_pallas(deltas: jax.Array, zcap: jax.Array,
                              block_t: int = 2048,
                              interpret: bool = True):
    """Blocked range-add scan + running-max feasibility in one pass.

    deltas: (T,) schedule range-adds in delta form; zcap: (T,) per-instant
    caps. Returns (occupancy (T,) float32, max excess occ - zcap, a float32
    scalar — feasible iff <= tolerance). Padding positions carry zcap =
    +big so they never win the max.
    """
    T = deltas.shape[0]
    num_blocks = -(-T // block_t)
    Tpad = num_blocks * block_t
    if Tpad != T:
        deltas = jnp.pad(deltas, (0, Tpad - T))
        zcap = jnp.pad(zcap, (0, Tpad - T), constant_values=-_NEG_BIG)
    occ, excess = pl.pallas_call(
        functools.partial(_feas_kernel, num_blocks=num_blocks),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_t,), lambda g: (g,)),
                  pl.BlockSpec((block_t,), lambda g: (g,))],
        out_specs=[pl.BlockSpec((block_t,), lambda g: (g,)),
                   pl.BlockSpec(memory_space=_SMEM)],
        out_shape=[jax.ShapeDtypeStruct((Tpad,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        scratch_shapes=[pltpu.SMEM((2,), jnp.float32)],
        interpret=interpret,
    )(deltas, zcap)
    return occ[:T], excess[0]