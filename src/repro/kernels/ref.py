"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are verified against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["next_use_ref", "evict_argmin_ref", "interval_occupancy_ref",
           "occupancy_feasible_ref"]


def next_use_ref(ids: jax.Array, num_objects: int) -> jax.Array:
    """next(t): index of the next request of ids[t], or T if none.

    Reverse scan carrying a last-seen table — the jnp analogue of the
    Pallas kernel's VMEM-resident table.
    """
    T = ids.shape[0]
    init = jnp.full((num_objects,), T, dtype=jnp.int32)

    def step(last_seen, t):
        i = ids[t]
        nxt = last_seen[i]
        return last_seen.at[i].set(t), nxt

    _, out = jax.lax.scan(step, init, jnp.arange(T - 1, -1, -1, dtype=jnp.int32))
    return out[::-1]


def evict_argmin_ref(scores: jax.Array, touch: jax.Array,
                     mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Lexicographic argmin of (score, touch) over masked entries.

    Returns (victim_index int32, victim_score). If nothing is cached the
    score is +big and index 0. This is the eviction decision of every
    priority policy (paper §2 "Policies"; DESIGN.md §3).
    """
    big = jnp.asarray(3.4e38, scores.dtype)
    s = jnp.where(mask, scores, big)
    min_s = jnp.min(s)
    tie = s <= min_s
    int_big = jnp.asarray(2**31 - 1, touch.dtype)
    victim = jnp.argmin(jnp.where(tie, touch, int_big)).astype(jnp.int32)
    return victim, s[victim]


def interval_occupancy_ref(deltas: jax.Array) -> jax.Array:
    """Inclusive prefix sum of per-position occupancy deltas.

    deltas[p] = sum of +s_i at interval starts / -s_i just past interval
    ends; the prefix sum is the LHS occupancy profile of eq. (2).
    """
    return jnp.cumsum(deltas, axis=0)


def occupancy_feasible_ref(deltas: jax.Array,
                           zcap: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Occupancy profile + worst excess over the per-instant cap.

    Returns (occ float32, max over tau of occ[tau] - zcap[tau]); the
    schedule is feasible iff the excess is <= tolerance. Semantics of the
    fused Pallas scan in interval_occupancy.py.
    """
    occ = jnp.cumsum(deltas.astype(jnp.float32), axis=0)
    return occ, jnp.max(occ - zcap.astype(jnp.float32))
