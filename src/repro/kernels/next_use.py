"""Pallas TPU kernel: next-use index computation (Belady / interval build).

The paper's offline machinery needs next(t) for every request — the Belady
oracles and the interval construction of eq. (2) both start from it. On GPU
this is a scatter in a backward loop; the TPU adaptation (DESIGN.md §3)
keeps the last-seen table resident in VMEM *scratch* and walks the request
stream in reverse, one VMEM-sized block of requests per sequential grid
step. TPU grids execute in order, so the scratch table carries across
blocks for free.

Layout: requests are processed in blocks of `block_t`; the table holds one
int32 slot per object (padded to a multiple of 128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["next_use_pallas"]


def _kernel(ids_ref, out_ref, table_ref, *, T: int, block_t: int,
            num_blocks: int):
    g = pl.program_id(0)
    # first sequential grid step: no object seen yet -> next use = T
    @pl.when(g == 0)
    def _init():
        table_ref[...] = jnp.full_like(table_ref, T)

    # this grid step handles requests [blk*block_t, ...) in reverse order
    blk = num_blocks - 1 - g
    base = blk * block_t

    def body(k, _):
        # position inside the block, walked back-to-front
        p = block_t - 1 - k
        t = base + p

        @pl.when(t < T)
        def _():
            i = ids_ref[p]
            out_ref[p] = table_ref[i]
            table_ref[i] = t
        return 0

    jax.lax.fori_loop(0, block_t, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("num_objects", "block_t", "interpret"))
def next_use_pallas(ids: jax.Array, num_objects: int, block_t: int = 1024,
                    interpret: bool = True) -> jax.Array:
    """next(t) for each request; T where the object never recurs.

    ids: (T,) int32 in [0, num_objects). Returns (T,) int32.
    """
    T = ids.shape[0]
    num_blocks = -(-T // block_t)
    Tpad = num_blocks * block_t
    if Tpad != T:
        ids = jnp.pad(ids, (0, Tpad - T))
    # pad the object table to the 128-lane boundary
    n_pad = -(-num_objects // 128) * 128
    grid = (num_blocks,)
    out = pl.pallas_call(
        functools.partial(_kernel, T=T, block_t=block_t,
                          num_blocks=num_blocks),
        grid=grid,
        # reverse-order block mapping: grid step g touches block G-1-g
        in_specs=[pl.BlockSpec((block_t,),
                               lambda g: (num_blocks - 1 - g,))],
        out_specs=pl.BlockSpec((block_t,), lambda g: (num_blocks - 1 - g,)),
        out_shape=jax.ShapeDtypeStruct((Tpad,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_pad,), jnp.int32)],
        interpret=interpret,
    )(ids.astype(jnp.int32))
    return out[:T]
