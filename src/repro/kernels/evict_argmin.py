"""Pallas TPU kernel: masked lexicographic argmin — the eviction decision.

Every priority policy's inner loop (LRU/LFU/GDS/GDSF/Belady/cost-Belady,
paper §2) is "find the cached object with the smallest (score, last_touch)".
A heap does not vectorize; on TPU the whole object table lives in VMEM and
the reduction runs at vector width (DESIGN.md §3). This kernel blocks the
table (BLOCK_N multiple of 128 lanes), keeps a running lexicographic
minimum in SMEM scratch across sequential grid steps, and emits the final
(victim index, victim score).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["evict_argmin_pallas"]

_BIG = 3.4e38
_INT_BIG = 2**31 - 1

# jax >= 0.5 renamed pltpu.TPUMemorySpace -> pltpu.MemorySpace; the SMEM
# constant exists under both spellings.
_SMEM = getattr(pltpu, "MemorySpace", getattr(pltpu, "TPUMemorySpace", None)).SMEM


def _kernel(scores_ref, touch_ref, mask_ref, idx_out, val_out,
            best_ref, *, block_n: int, num_blocks: int):
    g = pl.program_id(0)

    @pl.when(g == 0)
    def _init():
        best_ref[0] = jnp.float32(_BIG)   # best score
        best_ref[1] = jnp.float32(_INT_BIG)  # best touch (lex tiebreak)
        best_ref[2] = jnp.float32(-1)     # best index

    s = jnp.where(mask_ref[...], scores_ref[...].astype(jnp.float32),
                  jnp.float32(_BIG))
    local_min = jnp.min(s)
    tie = s <= local_min
    touch = jnp.where(tie, touch_ref[...], _INT_BIG)
    local_arg = jnp.argmin(touch)
    local_touch = touch[local_arg].astype(jnp.float32)
    local_idx = (g * block_n + local_arg).astype(jnp.float32)

    better = (local_min < best_ref[0]) | (
        (local_min == best_ref[0]) & (local_touch < best_ref[1]))

    @pl.when(better)
    def _upd():
        best_ref[0] = local_min
        best_ref[1] = local_touch
        best_ref[2] = local_idx

    @pl.when(g == num_blocks - 1)
    def _emit():
        safe = jnp.maximum(best_ref[2], 0.0)
        idx_out[0] = safe.astype(jnp.int32)
        val_out[0] = best_ref[0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def evict_argmin_pallas(scores: jax.Array, touch: jax.Array, mask: jax.Array,
                        block_n: int = 2048, interpret: bool = True):
    """Lexicographic argmin of (score, touch) over mask==True entries.

    scores: (N,) float; touch: (N,) int32; mask: (N,) bool.
    Returns (victim_index int32 scalar, victim_score float32 scalar);
    score is +BIG when the mask is empty.
    """
    n = scores.shape[0]
    num_blocks = -(-n // block_n)
    n_pad = num_blocks * block_n
    if n_pad != n:
        scores = jnp.pad(scores, (0, n_pad - n))
        touch = jnp.pad(touch, (0, n_pad - n))
        mask = jnp.pad(mask, (0, n_pad - n))
    idx, val = pl.pallas_call(
        functools.partial(_kernel, block_n=block_n, num_blocks=num_blocks),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_n,), lambda g: (g,)),
                  pl.BlockSpec((block_n,), lambda g: (g,)),
                  pl.BlockSpec((block_n,), lambda g: (g,))],
        out_specs=[pl.BlockSpec(memory_space=_SMEM),
                   pl.BlockSpec(memory_space=_SMEM)],
        out_shape=[jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        scratch_shapes=[pltpu.SMEM((3,), jnp.float32)],
        interpret=interpret,
    )(scores, touch.astype(jnp.int32), mask)
    return idx[0], val[0]