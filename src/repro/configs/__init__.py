"""Assigned architecture configs (exact figures from the assignment table)
plus reduced smoke configs and the paper's own benchmark config."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "kimi-k2-1t-a32b",
    "qwen2-moe-a2.7b",
    "xlstm-125m",
    "chatglm3-6b",
    "phi4-mini-3.8b",
    "mistral-nemo-12b",
    "gemma3-4b",
    "qwen2-vl-72b",
    "whisper-large-v3",
    "recurrentgemma-9b",
]


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str, smoke: bool = False):
    m = _module(arch_id)
    return m.SMOKE_CONFIG if smoke else m.CONFIG


def list_archs():
    return list(ARCH_IDS)


# ---- input-shape cells (assignment: LM shapes seq_len x global_batch) -----
SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4096,    global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768,   global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32768,   global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524288,  global_batch=1),
}

# long_500k needs sub-quadratic attention: run for SSM/hybrid/local-window
# archs, skip for pure full-attention archs (DESIGN.md §6)
LONG_OK = {"xlstm-125m", "recurrentgemma-9b", "gemma3-4b"}


def shape_applicable(arch_id: str, shape_id: str) -> bool:
    if shape_id == "long_500k":
        return arch_id in LONG_OK
    return True


def cells():
    """All applicable (arch, shape) dry-run cells."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            if shape_applicable(a, s):
                out.append((a, s))
    return out