"""recurrentgemma-9b — RG-LRU + local attn, 1:2 [arXiv:2402.19427; unverified].

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000; pattern
(rec, rec, local-attn), window 2048.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    attn_every=3, window=2048,
)

SMOKE_CONFIG = ArchConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=160, vocab_size=256, attn_every=3, window=16,
)
