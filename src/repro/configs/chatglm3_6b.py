"""chatglm3-6b — RoPE on half the head dim, strong GQA [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, rope_fraction=0.5,
)

SMOKE_CONFIG = ArchConfig(
    name="chatglm3-6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256, rope_fraction=0.5,
)
