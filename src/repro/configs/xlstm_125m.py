"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 (blocks carry their own projections) vocab=50304.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
)

SMOKE_CONFIG = ArchConfig(
    name="xlstm-125m-smoke", family="xlstm",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=256,
)
