"""whisper-large-v3 — enc-dec, conv frontend stubbed [arXiv:2212.04356].

32L decoder + 32L encoder, d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
input_specs feeds precomputed frame embeddings (assignment note).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, encoder_layers=32, act="gelu",
    cross_attend=True,
)

SMOKE_CONFIG = ArchConfig(
    name="whisper-large-v3-smoke", family="encdec",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=256, encoder_layers=2, act="gelu",
    cross_attend=True,
)
