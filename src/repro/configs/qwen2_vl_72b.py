"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. The vision tower
is a STUB: input_specs feeds precomputed patch embeddings (assignment note).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    num_vision_tokens=256, mrope_sections=(16, 24, 24),
)

SMOKE_CONFIG = ArchConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256,
    num_vision_tokens=16, mrope_sections=(2, 3, 3),
)
