"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    num_experts=60, top_k=4, num_shared_experts=4, d_expert=1408,
    capacity_factor=1.25,
)

SMOKE_CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=96, vocab_size=256,
    num_experts=6, top_k=2, num_shared_experts=2, d_expert=96,
    capacity_factor=1.25,
)
