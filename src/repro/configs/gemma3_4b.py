"""gemma3-4b — 5 local : 1 global attention, 128k [hf:google/gemma-3; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; local window 1024,
every 6th layer global.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    d_ff=10240, vocab_size=262144,
    window=1024, global_every=6, rope_theta=1e6,
)

SMOKE_CONFIG = ArchConfig(
    name="gemma3-4b-smoke", family="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256, window=16, global_every=6,
)
