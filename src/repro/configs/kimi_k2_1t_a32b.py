"""kimi-k2-1t-a32b — Kimi K2 trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384 experts
top-8, 1 shared expert, first layer dense (paper-table figures).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    num_experts=384, top_k=8, num_shared_experts=1, d_expert=2048,
    first_k_dense=1, capacity_factor=1.25,
)

SMOKE_CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    num_experts=8, top_k=2, num_shared_experts=1, d_expert=128,
    first_k_dense=1, capacity_factor=1.25,
)
