"""Billing-faithful span tracer: every dollar, attributed to a span.

A `Tracer` records a tree of timed spans — request -> cache lookup ->
store GET — where the spans that bill (store GETs) carry their exact
dollar attribution (`dollars = f + bytes * e`, the same float the
`BillingMeter` accrues) plus a size-vs-s* regime tag, so summing span
dollars for a consumer reproduces that consumer's meter total to float
tolerance (asserted in tests/test_obs.py).

Publishers hold the tracer duck-typed (`repro.egress` never imports this
module) and guard the hot path with plain truthiness: `NullTracer` (and a
disabled `Tracer`) are falsy, so `if tracer:` costs one branch and the
disabled overhead is ~0 (measured in bench_policy_throughput).

Exports: JSON (list of span dicts), Chrome trace-event format — complete
events (`"ph": "X"`) loadable in Perfetto / chrome://tracing — and
OTLP-shaped JSON (`resourceSpans`/`scopeSpans`, `to_otlp`) for collectors
that speak OpenTelemetry.

Span recording is bounded: the tracer keeps at most `max_spans` finished
spans (a ring; `dropped` counts the overflow), so tracing a long-running
server never grows without bound. For full-fidelity capture past the ring,
pass `stream=` a writable file object: every finished span is written
through as one NDJSON line at close time, so the stream holds spans the
ring has already evicted.
"""
from __future__ import annotations

import collections
import json
import math
import os
import pathlib
import threading
import time
from typing import Optional

__all__ = ["Span", "Tracer", "NullTracer", "regime_tag"]


def regime_tag(nbytes: float, crossover_bytes: float) -> str:
    """Which side of the paper's s* = f/e crossover a size falls on."""
    return "fee_dominated" if nbytes <= crossover_bytes else "egress_dominated"


class Span:
    """One timed operation. Mutable while open; frozen by convention after
    close. `attrs` carries the dollar attribution (`dollars`, `bytes`,
    `regime`, `consumer`, ...)."""

    __slots__ = ("name", "cat", "span_id", "parent_id", "t0", "dur", "tid",
                 "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 span_id: int, parent_id: Optional[int], t0: float):
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0          # seconds since tracer epoch
        self.dur = 0.0        # seconds
        self.tid = 0
        self.attrs: Optional[dict] = None
        self._tracer = tracer

    def set(self, **attrs) -> "Span":
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    # context-manager protocol (entry is implicit: Tracer.span() opens)
    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._tracer._close(self)

    def to_dict(self) -> dict:
        return dict(name=self.name, cat=self.cat, span_id=self.span_id,
                    parent_id=self.parent_id, ts_us=self.t0 * 1e6,
                    dur_us=self.dur * 1e6, tid=self.tid,
                    args=dict(self.attrs) if self.attrs else {})


class Tracer:
    """Span recorder with a per-thread open-span stack (nesting)."""

    def __init__(self, max_spans: int = 100_000, enabled: bool = True,
                 stream=None):
        self.enabled = enabled
        self.max_spans = int(max_spans)
        # perf_counter drives durations; the wall-clock epoch captured
        # alongside it anchors OTLP's unix-nano timestamps
        self._epoch = time.perf_counter()
        self._epoch_unix = time.time()
        self._spans: collections.deque[Span] = collections.deque(
            maxlen=self.max_spans)
        self._recorded = 0
        self._next_id = 1
        self._local = threading.local()
        self.stream = stream          # NDJSON write-through of closed spans
        self._stream_lock = threading.Lock()

    def __bool__(self) -> bool:
        return self.enabled

    # ---- recording --------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, cat: str = "span", **attrs) -> Span:
        """Open a span; close it via `with` (or `sp.__exit__(...)`)."""
        sp = self.begin(name, cat)
        if attrs:
            sp.attrs = attrs
        return sp

    def begin(self, name: str, cat: str = "span") -> Span:
        """Positional fast path of `span()` for per-access hot loops: no
        attr kwargs (assign `sp.attrs` directly), pair with `end()` in a
        try/finally instead of `with`."""
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        sid = self._next_id
        self._next_id = sid + 1
        sp = Span(self, name, cat, sid,
                  st[-1].span_id if st else None,
                  time.perf_counter() - self._epoch)
        st.append(sp)
        return sp

    def _close(self, sp: Span) -> None:
        sp.dur = (time.perf_counter() - self._epoch) - sp.t0
        sp.tid = threading.get_ident()
        st = getattr(self._local, "stack", None) or ()
        if st and st[-1] is sp:
            st.pop()
        else:                      # out-of-order close: drop up to this span
            while st:
                if st.pop() is sp:
                    break
        self._spans.append(sp)
        self._recorded += 1
        if self.stream is not None:
            line = json.dumps(sp.to_dict(), sort_keys=True)
            with self._stream_lock:
                self.stream.write(line + "\n")

    end = _close   # public pair of `begin()`

    # ---- querying ---------------------------------------------------------
    def spans(self, cat: Optional[str] = None, name: Optional[str] = None,
              **attr_filters) -> list[Span]:
        """Finished spans, optionally filtered by cat/name/attr equality."""
        out = []
        for sp in self._spans:
            if cat is not None and sp.cat != cat:
                continue
            if name is not None and sp.name != name:
                continue
            if attr_filters:
                a = sp.attrs or {}
                if any(a.get(k) != v for k, v in attr_filters.items()):
                    continue
            out.append(sp)
        return out

    def dollars(self, **filters) -> float:
        """Exact (fsum) total of `dollars` attrs over matching spans."""
        return math.fsum(sp.attrs.get("dollars", 0.0)
                         for sp in self.spans(**filters) if sp.attrs)

    @property
    def dropped(self) -> int:
        """Finished spans evicted from the ring by `max_spans`."""
        return self._recorded - len(self._spans)

    # ---- export -----------------------------------------------------------
    def to_dicts(self) -> list[dict]:
        return [sp.to_dict() for sp in self._spans]

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dicts(), indent=indent)

    def to_chrome_trace(self) -> dict:
        """Chrome trace-event format: complete events, ts/dur in us —
        loadable in Perfetto or chrome://tracing."""
        pid = os.getpid()
        events = []
        for sp in self._spans:
            events.append(dict(
                name=sp.name, cat=sp.cat, ph="X",
                ts=sp.t0 * 1e6, dur=sp.dur * 1e6,
                pid=pid, tid=sp.tid,
                args=dict(sp.attrs or {}, span_id=sp.span_id,
                          parent_id=sp.parent_id)))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_otlp(self, service_name: str = "repro") -> dict:
        """OTLP/JSON-shaped export (`resourceSpans`/`scopeSpans`), the
        schema OpenTelemetry collectors ingest: ids are zero-padded hex
        (spanId 16, traceId 32), times are unix-nano strings anchored at
        the wall-clock epoch captured next to the perf_counter epoch, and
        attrs map to typed `AnyValue`s. One tracer = one trace."""
        trace_id = f"{os.getpid() & 0xFFFFFFFFFFFFFFFF:016x}" \
                   f"{int(self._epoch_unix * 1e6) & 0xFFFFFFFFFFFFFFFF:016x}"
        spans = []
        for sp in self._spans:
            start = int((self._epoch_unix + sp.t0) * 1e9)
            attrs = [_otlp_attr("span.cat", sp.cat)]
            for k, v in (sp.attrs or {}).items():
                attrs.append(_otlp_attr(k, v))
            spans.append(dict(
                traceId=trace_id,
                spanId=f"{sp.span_id & 0xFFFFFFFFFFFFFFFF:016x}",
                parentSpanId=(f"{sp.parent_id & 0xFFFFFFFFFFFFFFFF:016x}"
                              if sp.parent_id is not None else ""),
                name=sp.name, kind=1,           # SPAN_KIND_INTERNAL
                startTimeUnixNano=str(start),
                endTimeUnixNano=str(start + int(sp.dur * 1e9)),
                attributes=attrs))
        return {"resourceSpans": [{
            "resource": {"attributes": [
                _otlp_attr("service.name", service_name)]},
            "scopeSpans": [{"scope": {"name": "repro.obs"},
                            "spans": spans}],
        }]}

    def write_json(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path

    def write_otlp(self, path, service_name: str = "repro") -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_otlp(service_name)) + "\n")
        return path

    def write_chrome_trace(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome_trace()) + "\n")
        return path


def _otlp_attr(key: str, v) -> dict:
    """One OTLP KeyValue; bool checked before int (bool is an int)."""
    if isinstance(v, bool):
        value = {"boolValue": v}
    elif isinstance(v, int):
        value = {"intValue": str(v)}       # OTLP/JSON carries i64 as string
    elif isinstance(v, float):
        value = {"doubleValue": v}
    else:
        value = {"stringValue": str(v)}
    return {"key": key, "value": value}


class _NullSpan:
    __slots__ = ()

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """API-compatible no-op; falsy so publishers skip it with one branch."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, cat: str = "span", **attrs) -> _NullSpan:
        return _NULL_SPAN

    def begin(self, name: str, cat: str = "span") -> _NullSpan:
        return _NULL_SPAN

    def end(self, sp) -> None:
        return None

    def spans(self, **filters) -> list:
        return []

    def dollars(self, **filters) -> float:
        return 0.0

    def to_dicts(self) -> list:
        return []

    def to_chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def to_otlp(self, service_name: str = "repro") -> dict:
        return {"resourceSpans": []}
