"""Metrics registry for the observability layer (DESIGN.md §9).

Promoted here from `repro.online.metrics` (which re-exports it for
back-compat): a single process-local registry of counters, gauges, time
series and — new in the obs layer — log-bucketed histograms, that
`ObjectStore`, `EgressCache`, `ServeEngine`, and the dollar-governor all
publish through. Publishers hold it duck-typed (anything with `.inc` /
`.set_gauge` / `.observe` / `.observe_hist`), so the egress layer never
imports this module — `repro.obs` sits strictly above `repro.egress`.

Histograms are Prometheus-shaped (le-bucketed cumulative on export, with
`_sum` and `_count`); the stock bucket layouts are geometric:
`log_bounds` for per-GET dollars (they span ~1e-9..1e-2 $), and
`sstar_bounds` for object sizes — octaves centered on the paper's
crossover s* = f/e, so the fee-dominated/egress-dominated split is
readable straight off the bucket counts.

Export is JSON (`to_json` / `write_json`) and Prometheus text exposition
(`to_prometheus` / `write_prometheus`).
"""
from __future__ import annotations

import bisect
import json
import pathlib
import re
import threading
from typing import Optional, Sequence

__all__ = ["MetricsRegistry", "Histogram", "log_bounds", "sstar_bounds"]


def log_bounds(lo: float, hi: float, per_decade: int = 3) -> list[float]:
    """Geometric bucket upper bounds covering [lo, hi]."""
    assert lo > 0 and hi > lo and per_decade >= 1
    out = [lo]
    ratio = 10.0 ** (1.0 / per_decade)
    while out[-1] < hi:
        out.append(out[-1] * ratio)
    return out


def sstar_bounds(crossover_bytes: float, octaves: int = 8) -> list[float]:
    """Size buckets centered on s* = f/e: s* * 2^k for k in [-octaves,
    octaves]. s* itself is a bucket boundary, so the counts at or below
    the s* bound are exactly the fee-dominated accesses."""
    return [crossover_bytes * 2.0 ** k for k in range(-octaves, octaves + 1)]


# default when a publisher doesn't pick bounds: wide geometric coverage
_DEFAULT_BOUNDS = log_bounds(1e-9, 1e3, per_decade=1)


class Histogram:
    """le-bucketed histogram: counts[i] = observations <= bounds[i],
    stored non-cumulative; the +Inf overflow is counts[-1]."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float]):
        b = [float(x) for x in bounds]
        assert b == sorted(b) and len(b) >= 1, "bounds must be ascending"
        self.bounds = b
        self.counts = [0] * (len(b) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.sum += value
        self.count += 1

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def snapshot(self) -> dict:
        return dict(bounds=list(self.bounds), counts=list(self.counts),
                    sum=self.sum, count=self.count)


def _prom_name(name: str) -> str:
    n = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    return n if re.match(r"[a-zA-Z_:]", n) else "_" + n


def _prom_num(v: float) -> str:
    return repr(float(v))


class MetricsRegistry:
    """Counters (monotone), gauges (last value), series ((step, value)
    lists), histograms (le-bucketed)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.series: dict[str, list[tuple[int, float]]] = {}
        self.histograms: dict[str, Histogram] = {}
        self._step = 0

    # ---- publishing -------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float,
                step: Optional[int] = None) -> None:
        """Append to a time series; `step` defaults to an internal tick."""
        with self._lock:
            if step is None:
                self._step += 1
                step = self._step
            self.series.setdefault(name, []).append((int(step), float(value)))

    def observe_hist(self, name: str, value: float,
                     bounds: Optional[Sequence[float]] = None) -> None:
        """Record into a histogram, creating it on first use with `bounds`
        (or the wide geometric default). Bounds are fixed at creation —
        later `bounds` arguments are ignored (buckets can't be re-binned)."""
        with self._lock:
            h = self.histograms.get(name)
            if h is None:
                h = self.histograms[name] = Histogram(
                    bounds if bounds is not None else _DEFAULT_BOUNDS)
            h.observe(value)

    # ---- reading / export -------------------------------------------------
    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def latest(self, name: str) -> Optional[float]:
        s = self.series.get(name)
        return s[-1][1] if s else None

    def hist(self, name: str) -> Optional[Histogram]:
        return self.histograms.get(name)

    def snapshot(self) -> dict:
        with self._lock:
            return dict(
                counters=dict(self.counters),
                gauges=dict(self.gauges),
                series={k: [list(p) for p in v]
                        for k, v in self.series.items()},
                histograms={k: h.snapshot()
                            for k, h in self.histograms.items()},
            )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def write_json(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path

    def to_prometheus(self) -> str:
        """Prometheus text exposition (text/plain; version 0.0.4).

        Counters and gauges expose as-is; a time series exposes its latest
        value as a gauge; histograms expose cumulative `_bucket{le=...}`
        lines plus `_sum` / `_count`."""
        with self._lock:
            lines: list[str] = []
            for name in sorted(self.counters):
                n = _prom_name(name)
                lines.append(f"# TYPE {n} counter")
                lines.append(f"{n} {_prom_num(self.counters[name])}")
            for name in sorted(self.gauges):
                n = _prom_name(name)
                lines.append(f"# TYPE {n} gauge")
                lines.append(f"{n} {_prom_num(self.gauges[name])}")
            for name in sorted(self.series):
                if not self.series[name]:
                    continue
                n = _prom_name(name) + "_last"
                lines.append(f"# TYPE {n} gauge")
                lines.append(f"{n} {_prom_num(self.series[name][-1][1])}")
            for name in sorted(self.histograms):
                h = self.histograms[name]
                n = _prom_name(name)
                lines.append(f"# TYPE {n} histogram")
                cum = h.cumulative()
                for b, c in zip(h.bounds, cum):
                    lines.append(f'{n}_bucket{{le="{b:g}"}} {c}')
                lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
                lines.append(f"{n}_sum {_prom_num(h.sum)}")
                lines.append(f"{n}_count {h.count}")
            return "\n".join(lines) + "\n"

    def write_prometheus(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_prometheus())
        return path
