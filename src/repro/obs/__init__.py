# Observability for the egress stack (DESIGN.md §9) — explains every dollar:
#   trace   — span tracer (request -> cache lookup -> store GET) with exact
#             per-span dollar attribution; JSON + Chrome trace-event export
#   events  — ring-buffered cache decision log (hit/miss/admit/reject/evict/
#             policy_swap) with per-event dollar deltas
#   metrics — promoted MetricsRegistry: counters/gauges/series + log-bucketed
#             histograms (sizes centered on s*, per-GET dollars, regret);
#             JSON + Prometheus text exposition
#   schema  — dependency-free JSON-Schema subset validator for the artifacts
# Layering rule: repro.egress never imports repro.obs — every publisher is
# duck-typed (tracer, events, metrics), exactly like PR 7's registry.
from .trace import NullTracer, Span, Tracer, regime_tag
from .events import EVENT_KINDS, DecisionEvent, EventLog
from .metrics import Histogram, MetricsRegistry, log_bounds, sstar_bounds
from .schema import validate

__all__ = [
    "Tracer", "NullTracer", "Span", "regime_tag",
    "EventLog", "DecisionEvent", "EVENT_KINDS",
    "MetricsRegistry", "Histogram", "log_bounds", "sstar_bounds",
    "validate",
]
