"""Dependency-free JSON-Schema subset validator for the obs artifacts.

CI exports a governed-serve obs snapshot (`examples/trace_a_request.py`)
and validates it against the checked-in schema `tests/schemas/obs.json`
without installing `jsonschema`. The supported keyword subset — `type`
(string or list), `properties`, `required`, `items`,
`additionalProperties` (bool or schema), `enum`, `minimum`, `maximum` —
covers everything the obs schema uses; unknown keywords are ignored, as
JSON Schema itself specifies.

CLI:  python -m repro.obs.schema <instance.json> <schema.json>
exits non-zero listing every violation.
"""
from __future__ import annotations

import json
import sys

__all__ = ["validate", "validate_file"]

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "integer": int,
    "number": (int, float),
    "boolean": bool,
    "null": type(None),
}


def _type_ok(value, tname: str) -> bool:
    py = _TYPES[tname]
    if isinstance(value, bool):          # bool is an int in Python; JSON isn't
        return tname == "boolean"
    return isinstance(value, py)


def validate(instance, schema: dict, path: str = "$") -> list[str]:
    """All violations of `schema` by `instance` (empty list = valid)."""
    errors: list[str] = []
    t = schema.get("type")
    if t is not None:
        names = t if isinstance(t, list) else [t]
        if not any(_type_ok(instance, n) for n in names):
            errors.append(f"{path}: expected type {t}, "
                          f"got {type(instance).__name__}")
            return errors           # deeper keywords assume the right type
    if "enum" in schema and instance not in schema["enum"]:
        errors.append(f"{path}: {instance!r} not in enum {schema['enum']}")
    if isinstance(instance, (int, float)) and not isinstance(instance, bool):
        if "minimum" in schema and instance < schema["minimum"]:
            errors.append(f"{path}: {instance} < minimum {schema['minimum']}")
        if "maximum" in schema and instance > schema["maximum"]:
            errors.append(f"{path}: {instance} > maximum {schema['maximum']}")
    if isinstance(instance, dict):
        props = schema.get("properties", {})
        for req in schema.get("required", []):
            if req not in instance:
                errors.append(f"{path}: missing required key {req!r}")
        for k, v in instance.items():
            if k in props:
                errors += validate(v, props[k], f"{path}.{k}")
            else:
                ap = schema.get("additionalProperties", True)
                if ap is False:
                    errors.append(f"{path}: unexpected key {k!r}")
                elif isinstance(ap, dict):
                    errors += validate(v, ap, f"{path}.{k}")
    if isinstance(instance, list) and "items" in schema:
        for i, v in enumerate(instance):
            errors += validate(v, schema["items"], f"{path}[{i}]")
    return errors


def validate_file(instance_path: str, schema_path: str) -> list[str]:
    with open(instance_path) as f:
        instance = json.load(f)
    with open(schema_path) as f:
        schema = json.load(f)
    return validate(instance, schema)


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print("usage: python -m repro.obs.schema <instance.json> "
              "<schema.json>", file=sys.stderr)
        return 2
    errors = validate_file(argv[0], argv[1])
    if errors:
        for e in errors:
            print(f"schema violation: {e}", file=sys.stderr)
        return 1
    print(f"{argv[0]}: valid against {argv[1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
