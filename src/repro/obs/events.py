"""Decision event log: every cache decision, with its dollar delta.

`EgressCache` publishes one `DecisionEvent` per decision — hit / miss /
admit / reject / evict / policy_swap — through a duck-typed publisher
(anything with `.record(kind, ...)`; the egress layer never imports this
module). `EventLog` is the concrete publisher: a bounded ring buffer
(`collections.deque(maxlen=...)`) plus per-kind counts and dollar totals
that survive ring eviction. The ring holds plain tuples (a `DecisionEvent`
is materialized lazily on read) and the totals are O(1) running sums
accumulated in the same order, with the same naive IEEE-754 addition, as
`BillingMeter` accrues its own dollars — so the lifetime `miss` total is
bit-equal to what the meter billed, with bounded memory.

Dollar semantics (DESIGN.md §9):
  * `dollar_delta`   — dollars actually billed by this event: the miss
    cost on a `miss`, 0.0 for every other kind (hits, evictions and
    swaps bill nothing *now*).
  * `dollars_at_stake` — the object's miss cost c = f + s*e at the
    decision: what a `hit` saved, what a `reject`/`evict` re-exposes on
    the next touch, what an `admit` shields. Uniform across kinds so
    event streams can be integrated either way.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math
import pathlib
from typing import Optional

__all__ = ["DecisionEvent", "EventLog", "EVENT_KINDS"]


EVENT_KINDS = ("hit", "miss", "admit", "reject", "evict", "policy_swap")


@dataclasses.dataclass(frozen=True, slots=True)
class DecisionEvent:
    kind: str
    key: str
    nbytes: int
    dollar_delta: float       # billed by this event (miss cost on a miss)
    dollars_at_stake: float   # the object's miss cost at decision time
    clock: int                # cache clock at the decision
    policy: str               # policy in effect (new policy on policy_swap)


class EventLog:
    """Ring-buffered decision log with lifetime per-kind accounting."""

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        # ring of raw field tuples; DecisionEvent is built lazily on read
        self._ring: collections.deque[tuple] = collections.deque(
            maxlen=self.capacity)
        self.counts: dict[str, int] = {k: 0 for k in EVENT_KINDS}
        self._dollar_delta: dict[str, float] = {}
        self._at_stake: dict[str, float] = {}
        self.recorded = 0

    # ---- publishing (the duck-typed surface EgressCache calls) ------------
    def record(self, kind: str, key: str, nbytes: int, dollar_delta: float,
               dollars_at_stake: float, clock: int, policy: str) -> None:
        self._ring.append((kind, key, nbytes, dollar_delta,
                           dollars_at_stake, clock, policy))
        counts = self.counts
        counts[kind] = counts.get(kind, 0) + 1
        dd = self._dollar_delta
        dd[kind] = dd.get(kind, 0.0) + dollar_delta
        ds = self._at_stake
        ds[kind] = ds.get(kind, 0.0) + dollars_at_stake
        self.recorded += 1

    # ---- reading ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._ring)

    @property
    def dropped(self) -> int:
        return self.recorded - len(self._ring)

    def events(self, kind: Optional[str] = None) -> list[DecisionEvent]:
        if kind is None:
            return [DecisionEvent(*t) for t in self._ring]
        return [DecisionEvent(*t) for t in self._ring if t[0] == kind]

    def dollars_billed(self, kind: Optional[str] = None) -> float:
        """Lifetime billed dollars (all events ever recorded, not just the
        ring window). Accumulated in meter order with meter arithmetic, so
        `dollars_billed("miss")` equals the consumer's `BillingMeter`
        total exactly."""
        if kind is not None:
            return self._dollar_delta.get(kind, 0.0)
        return math.fsum(self._dollar_delta.values())

    def dollars_at_stake(self, kind: str) -> float:
        return self._at_stake.get(kind, 0.0)

    def snapshot(self) -> dict:
        fields = ("kind", "key", "nbytes", "dollar_delta",
                  "dollars_at_stake", "clock", "policy")
        return dict(
            capacity=self.capacity,
            recorded=self.recorded,
            dropped=self.dropped,
            counts=dict(self.counts),
            dollars_billed=dict(self._dollar_delta),
            dollars_at_stake=dict(self._at_stake),
            window=[dict(zip(fields, t)) for t in self._ring],
        )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def write_json(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_json() + "\n")
        return path
