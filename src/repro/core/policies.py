"""Online cache replacement policies, scored in dollars.

Reference (host/Python) implementations of the policies the paper measures:
LRU, LFU, GreedyDual-Size (GDS), GDSF, Belady (hit-rate oracle) and a
cost-aware Belady heuristic. All are prior work (Cao & Irani 1997; Belady
1966); the paper measures them against the exact dollar optimum.

Every policy is scored identically: each miss of object i adds `cost[i]`
dollars (eq. 1); objects occupy `sizes[i]` bytes of a capacity-B cache.
The JAX lax.scan simulator in `policies_jax.py` is validated step-for-step
against these.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

import numpy as np

from .trace import Trace, next_use_indices

__all__ = ["PolicyResult", "simulate", "POLICIES", "total_cost_no_cache"]


@dataclasses.dataclass
class PolicyResult:
    policy: str
    dollars: float         # total billed cost of all misses
    misses: int
    hits: int
    evictions: int

    @property
    def requests(self) -> int:
        return self.misses + self.hits


def total_cost_no_cache(trace: Trace, costs: np.ndarray) -> float:
    return float(costs[trace.ids].sum())


class _PriorityCache:
    """Size-aware cache with a lazy-deletion heap keyed by a priority fn.

    Evicts the *smallest* (priority, last_touch, id) first — the explicit
    last-touch tiebreak keeps eviction order deterministic and identical to
    the JAX lax.scan simulator. Supports GreedyDual's aging L via
    `inflation`.
    """

    def __init__(self, capacity: float):
        self.capacity = capacity
        self.used = 0.0
        self.prio: dict[int, tuple[float, int]] = {}  # i -> (priority, touch)
        self.heap: list[tuple[float, int, int]] = []
        self.inflation = 0.0  # GreedyDual "L"

    def __contains__(self, i: int) -> bool:
        return i in self.prio

    def touch(self, i: int, priority: float, t: int) -> None:
        self.prio[i] = (priority, t)
        heapq.heappush(self.heap, (priority, t, i))

    def evict_until_fits(self, need: float, sizes: np.ndarray) -> int:
        evictions = 0
        while self.used + need > self.capacity and self.prio:
            p, tt, i = heapq.heappop(self.heap)
            if self.prio.get(i) != (p, tt):
                continue  # stale heap entry
            del self.prio[i]
            self.used -= sizes[i]
            self.inflation = p  # GreedyDual aging: L := priority of victim
            evictions += 1
        return evictions

    def insert(self, i: int, priority: float, t: int, sizes: np.ndarray) -> None:
        self.prio[i] = (priority, t)
        self.used += sizes[i]
        heapq.heappush(self.heap, (priority, t, i))


def _simulate_priority(trace: Trace, costs: np.ndarray, capacity: float,
                       priority_fn: Callable, name: str,
                       use_inflation: bool) -> PolicyResult:
    """Generic priority-policy simulator.

    priority_fn(t, i, freq, inflation) -> float; eviction removes min priority.
    """
    sizes = trace.sizes
    cache = _PriorityCache(capacity)
    freq = np.zeros(trace.num_objects, dtype=np.int64)
    dollars = 0.0
    misses = hits = evictions = 0
    for t, i in enumerate(trace.ids):
        freq[i] += 1
        infl = cache.inflation if use_inflation else 0.0
        if i in cache:
            hits += 1
            cache.touch(int(i), priority_fn(t, int(i), freq, infl), t)
            continue
        misses += 1
        dollars += float(costs[i])
        if sizes[i] > capacity:
            continue  # uncacheable object: fetch-through
        evictions += cache.evict_until_fits(sizes[i], sizes)
        infl = cache.inflation if use_inflation else 0.0
        cache.insert(int(i), priority_fn(t, int(i), freq, infl), t, sizes)
    return PolicyResult(name, dollars, misses, hits, evictions)


def lru(trace: Trace, costs: np.ndarray, capacity: float) -> PolicyResult:
    return _simulate_priority(
        trace, costs, capacity,
        lambda t, i, freq, infl: float(t), "lru", use_inflation=False)


def lfu(trace: Trace, costs: np.ndarray, capacity: float) -> PolicyResult:
    # ties broken by last touch (earliest evicted) via the cache's heap key
    return _simulate_priority(
        trace, costs, capacity,
        lambda t, i, freq, infl: float(freq[i]), "lfu", use_inflation=False)


def gds(trace: Trace, costs: np.ndarray, capacity: float) -> PolicyResult:
    """GreedyDual-Size: H = L + c_i / s_i (Cao & Irani 1997)."""
    return _simulate_priority(
        trace, costs, capacity,
        lambda t, i, freq, infl: infl + costs[i] / trace.sizes[i],
        "gds", use_inflation=True)


def gdsf(trace: Trace, costs: np.ndarray, capacity: float) -> PolicyResult:
    """GDS-Frequency: H = L + f_i * c_i / s_i."""
    return _simulate_priority(
        trace, costs, capacity,
        lambda t, i, freq, infl: infl + freq[i] * costs[i] / trace.sizes[i],
        "gdsf", use_inflation=True)


def _simulate_oracle(trace: Trace, costs: np.ndarray, capacity: float,
                     value_fn: Callable, name: str) -> PolicyResult:
    """Belady-style oracle: evict the cached object with the *largest*
    value_fn(next_use, i) — for Belady that is simply the farthest next use;
    for cost-aware Belady it discounts by the dollars at stake.

    Matches the paper's eq. (2) model: the fetched object always occupies a
    slot while being served (no bypass), so eviction-to-fit is mandatory.
    """
    sizes = trace.sizes
    nxt_req = next_use_indices(trace.ids, trace.num_objects)
    cached: dict[int, int] = {}   # object -> its next use time (T = never)
    touch: dict[int, int] = {}    # object -> last touch step (tiebreak)
    used = 0.0
    dollars = 0.0
    misses = hits = evictions = 0
    for t, i in enumerate(trace.ids):
        i = int(i)
        if i in cached:
            hits += 1
            cached[i] = int(nxt_req[t])
            touch[i] = t
            continue
        misses += 1
        dollars += float(costs[i])
        if sizes[i] > capacity:
            continue  # uncacheable object: fetch-through
        while used + sizes[i] > capacity and cached:
            # evict max value; ties -> earliest-touched (matches the JAX sim)
            victim = max(cached, key=lambda j: (value_fn(cached[j], j, t),
                                                -touch[j]))
            del cached[victim]
            del touch[victim]
            used -= sizes[victim]
            evictions += 1
        cached[i] = int(nxt_req[t])
        touch[i] = t
        used += sizes[i]
    return PolicyResult(name, dollars, misses, hits, evictions)


def belady(trace: Trace, costs: np.ndarray, capacity: float) -> PolicyResult:
    """Classic Belady: evict farthest-in-future (hit-rate oracle, $-scored)."""
    return _simulate_oracle(trace, costs, capacity,
                            lambda nu, i, t: float(nu), "belady")


def cost_belady(trace: Trace, costs: np.ndarray, capacity: float) -> PolicyResult:
    """Cost-aware Belady heuristic: evict the object whose retention saves the
    fewest dollars per byte-step — value = c_i / (s_i * steps_until_reuse);
    evict the largest badness = s_i * (nu - t_now) / c_i first."""
    T = trace.num_requests

    def badness(nu: int, i: int, t: int) -> float:
        if nu >= T:
            return float("inf")  # never reused: always the best victim
        gap = max(nu - t, 1)
        return trace.sizes[i] * gap / max(costs[i], 1e-30)

    return _simulate_oracle(trace, costs, capacity, badness, "cost_belady")


POLICIES: dict[str, Callable[[Trace, np.ndarray, float], PolicyResult]] = {
    "lru": lru,
    "lfu": lfu,
    "gds": gds,
    "gdsf": gdsf,
    "belady": belady,
    "cost_belady": cost_belady,
}


def simulate(policy: str, trace: Trace, costs: np.ndarray,
             capacity: float) -> PolicyResult:
    return POLICIES[policy](trace, costs, capacity)
