"""JAX (lax.scan) cache-policy simulator — the TPU-native replay engine.

The paper's sweep experiments replay the same trace under hundreds of
(policy, price-vector, budget) cells. Sequential heap-based simulation does
not vectorize; here each policy step is a pure function over fixed-size
state arrays and the whole replay is one `lax.scan`, vmap-able across cells
and jit-able onto accelerators.

Policies are encoded as *score weights*: the victim is the cached object
with the minimum score, where

  score(i) = w_t * last_touch(i)                     (LRU)
           + w_f * freq(i)                           (LFU)
           + w_gd   * (L + c_i / s_i)                (GreedyDual-Size)
           + w_gdsf * (L + freq(i) * c_i / s_i)      (GDSF)
           + w_bel  * (-next_use(i))                 (Belady: evict farthest)
           + w_cb   * (-(s_i * gap_i / c_i))         (cost-aware Belady)

Because policies are just weight vectors, a whole policy *panel* batches as
one more vmap axis: `sweep_jax` compiles a single (policies x price-vectors
x budgets) grid program, the device-resident form of the paper's regime
maps (DESIGN.md §3).

Victim selection dispatches through `kernels.evict_argmin`: the Pallas TPU
kernel on TPU backends (`use_pallas=None` -> `on_tpu()`), the pure-jnp
reduction elsewhere; both implement the same lexicographic argmin and are
checked step-for-step against each other in tests/test_policies_jax.py.

Uniform-size mode (the paper's exact-reference regime): one eviction per
miss, no data-dependent loop. Variable sizes stay on the host reference
(`policies.py`); see DESIGN.md §3.

Validated step-for-step against `policies.py` in tests/test_policies_jax.py.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .trace import next_use_indices
from ..kernels import ops

__all__ = ["PolicyWeights", "POLICY_WEIGHTS", "simulate_jax", "sweep_jax",
           "stack_policy_weights"]

_BIG = jnp.float32(3.4e38)


@dataclasses.dataclass(frozen=True)
class PolicyWeights:
    w_t: float = 0.0
    w_f: float = 0.0
    w_gd: float = 0.0
    w_gdsf: float = 0.0
    w_bel: float = 0.0
    w_cb: float = 0.0

    def as_array(self) -> np.ndarray:
        return np.array([self.w_t, self.w_f, self.w_gd, self.w_gdsf,
                         self.w_bel, self.w_cb], dtype=np.float32)


POLICY_WEIGHTS: dict[str, PolicyWeights] = {
    "lru": PolicyWeights(w_t=1.0),
    "lfu": PolicyWeights(w_f=1.0, w_t=1e-12),
    "gds": PolicyWeights(w_gd=1.0),
    "gdsf": PolicyWeights(w_gdsf=1.0),
    "belady": PolicyWeights(w_bel=1.0),
    "cost_belady": PolicyWeights(w_cb=1.0),
}


def stack_policy_weights(policies: Sequence[str | PolicyWeights]) -> np.ndarray:
    """(Q, 6) weight stack for a policy panel — the third sweep axis."""
    rows = []
    for p in policies:
        w = POLICY_WEIGHTS[p] if isinstance(p, str) else p
        rows.append(w.as_array())
    return np.stack(rows)


def _static_score(w, t, freq_i, infl, c_over_s):
    """Frozen-at-touch score components (LRU / LFU / GDS / GDSF)."""
    return (w[0] * t + w[1] * freq_i
            + w[2] * (infl + c_over_s)
            + w[3] * (infl + freq_i * c_over_s))


@functools.partial(jax.jit,
                   static_argnames=("num_objects", "use_pallas", "trace_steps"))
def _simulate(ids, nxt, costs, sizes, capacity, weights, num_objects: int,
              use_pallas: bool = False, trace_steps: bool = False):
    """One policy replay, uniform-size pages. Returns (dollars, hits).

    Victim = lexicographic argmin of (score, last_touch) over cached objects,
    where score = static (frozen at touch) + dynamic (Belady / cost-Belady,
    evaluated at eviction time from the stored next-use index). This exactly
    matches the heap key of the Python reference.

    `use_pallas` routes the victim argmin through the Pallas TPU kernel
    (`kernels.evict_argmin`) instead of the jnp reduction — the replay
    engine's eviction hot path on real TPUs. `trace_steps` additionally
    returns the per-step (dollars, hits) trajectory for step-for-step
    equivalence tests.
    """
    T = ids.shape[0]
    n = num_objects
    c_over_s = (costs / jnp.maximum(sizes, 1e-30)).astype(jnp.float32)
    INT_BIG = jnp.int32(2**31 - 1)

    def total_scores(static, stored_nxt, t):
        """static + dynamic part, per object."""
        nxtf = stored_nxt.astype(jnp.float32)
        gap = jnp.maximum(nxtf - t, 1.0)
        never = stored_nxt >= T
        # belady: evict max next-use  -> score -nxt (never-reused = -BIG)
        bel = jnp.where(never, -_BIG, -nxtf)
        # cost-belady: evict max s*gap/c -> score -(s*gap/c)
        cb = jnp.where(never, -_BIG, -(sizes * gap / jnp.maximum(costs, 1e-30)))
        return static + weights[4] * bel + weights[5] * cb

    def step(state, inp):
        cached, static, stored_nxt, touch, freq, used, infl, dollars, hits = state
        t, i, nu = inp
        tf = t.astype(jnp.float32)
        freq = freq.at[i].add(1)
        is_hit = cached[i]
        dollars = dollars + jnp.where(is_hit, 0.0, costs[i])
        hits = hits + is_hit.astype(jnp.int32)

        # victim: lexicographic argmin of (score, last_touch) among cached\{i}
        mask = cached.at[i].set(False)
        raw = total_scores(static, stored_nxt, tf)
        if use_pallas:
            victim, victim_score = ops.evict_argmin(raw, touch, mask,
                                                    use_pallas=True)
        else:
            scores = jnp.where(mask, raw, _BIG)
            min_s = jnp.min(scores)
            tie = scores <= min_s  # exact equality; _BIG rows excluded by min
            victim = jnp.argmin(jnp.where(tie, touch, INT_BIG))
            victim_score = scores[victim]
        full = used >= capacity

        # eq.-(2) semantics: a miss always inserts (mandatory displacement)
        do_insert = ~is_hit
        do_evict = do_insert & full & (victim_score < _BIG)
        cached = cached.at[victim].set(jnp.where(do_evict, False, cached[victim]))
        # GreedyDual aging: L := priority of the evicted victim
        gd_active = (weights[2] + weights[3]) > 0
        infl = jnp.where(do_evict & gd_active, victim_score, infl)
        my_static = _static_score(weights, tf, freq[i].astype(jnp.float32),
                                  infl, c_over_s[i])
        used = used - jnp.where(do_evict, 1, 0) + jnp.where(do_insert, 1, 0)
        cached = cached.at[i].set(cached[i] | do_insert)
        # touches (hit or insert) refresh score, next-use and touch time
        static = static.at[i].set(my_static)
        stored_nxt = stored_nxt.at[i].set(nu)
        touch = touch.at[i].set(t)
        new_state = (cached, static, stored_nxt, touch, freq, used, infl,
                     dollars, hits)
        return new_state, ((dollars, hits) if trace_steps else None)

    init = (jnp.zeros(n, bool), jnp.full(n, _BIG, jnp.float32),
            jnp.full(n, T, jnp.int32), jnp.zeros(n, jnp.int32),
            jnp.zeros(n, jnp.int32), jnp.int32(0), jnp.float32(0.0),
            jnp.float32(0.0), jnp.int32(0))
    ts = jnp.arange(T, dtype=jnp.int32)
    final, traj = jax.lax.scan(step, init, (ts, ids, nxt))
    if trace_steps:
        return final[-2], final[-1], traj
    return final[-2], final[-1]


def _resolve_use_pallas(use_pallas: bool | None) -> bool:
    """None -> the backend default: Pallas kernel on TPU, jnp elsewhere."""
    return ops.on_tpu() if use_pallas is None else use_pallas


def simulate_jax(policy: str, ids: np.ndarray, costs: np.ndarray,
                 capacity_pages: int, num_objects: int | None = None,
                 sizes: np.ndarray | None = None,
                 use_pallas: bool | None = None):
    """Replay one policy on a uniform-size page trace. Returns (dollars, hits).

    `sizes` only affects the cost-density terms of GDS/GDSF/cost-Belady
    (the cache itself is page-uniform, matching the exact reference)."""
    ids = np.asarray(ids, dtype=np.int32)
    n = int(num_objects if num_objects is not None else ids.max() + 1)
    nxt = next_use_indices(ids).astype(np.int32)
    w = POLICY_WEIGHTS[policy].as_array()
    s = np.ones(n, np.float32) if sizes is None else np.asarray(sizes, np.float32)
    d, h = _simulate(jnp.asarray(ids), jnp.asarray(nxt),
                     jnp.asarray(costs, dtype=jnp.float32), jnp.asarray(s),
                     jnp.int32(capacity_pages), jnp.asarray(w), n,
                     _resolve_use_pallas(use_pallas))
    return float(d), int(h)


def _sweep_grid(weight_stack, ids, nxt, cost_matrix, sizes, budgets,
                num_objects: int, use_pallas: bool):
    """(Q policies x P prices x K budgets) grid as one compiled program."""

    def one(w, costs, B):
        d, _ = _simulate(ids, nxt, costs, sizes, B, w, num_objects,
                         use_pallas)
        return d

    f = jax.vmap(                                   # policies
        jax.vmap(                                   # price vectors
            jax.vmap(one, in_axes=(None, None, 0)),  # budgets
            in_axes=(None, 0, None)),
        in_axes=(0, None, None))
    return f(weight_stack, cost_matrix, budgets)


@functools.cache
def _sweep_grid_jit(donate: bool):
    """Jit the grid once per donation mode. The stacked weights and the
    price matrix are consumed by the sweep (freshly staged per call), so on
    accelerators their buffers are donated; CPU jit would only warn."""
    return jax.jit(_sweep_grid,
                   static_argnames=("num_objects", "use_pallas"),
                   donate_argnums=(0, 3) if donate else ())


def sweep_jax(policy, ids: np.ndarray, cost_matrix: np.ndarray,
              budgets: np.ndarray, num_objects: int | None = None,
              sizes: np.ndarray | None = None,
              use_pallas: bool | None = None,
              profile: dict | None = None) -> np.ndarray:
    """Batched replay of a (policy x price-vector x budget) grid on device.

    policy:      one policy name -> dollars of shape (P, K);
                 a sequence of names / `PolicyWeights` (or a pre-stacked
                 (Q, 6) float array) -> dollars of shape (Q, P, K), all Q
                 policies replayed inside the SAME compiled scan program.
    cost_matrix: (P, N) per-object costs for P price vectors.
    budgets:     (K,) page budgets.
    profile:     pass a dict to get compile time separated from execute
                 time (DESIGN.md §9): filled with `compile_s` (trace +
                 lower + XLA compile, ~0 when the executable is already
                 cached) and `execute_s` (device run, block_until_ready).
    """
    single = isinstance(policy, str)
    if single:
        stack = stack_policy_weights([policy])
    elif isinstance(policy, np.ndarray) or isinstance(policy, jax.Array):
        stack = np.asarray(policy, dtype=np.float32)
        if stack.ndim != 2 or stack.shape[1] != 6:
            raise ValueError("weight stack must have shape (Q, 6)")
    else:
        stack = stack_policy_weights(policy)
    ids = np.asarray(ids, dtype=np.int32)
    n = int(num_objects if num_objects is not None else ids.max() + 1)
    nxt = jnp.asarray(next_use_indices(ids).astype(np.int32))
    s = jnp.ones(n, jnp.float32) if sizes is None else jnp.asarray(sizes, jnp.float32)
    fn = _sweep_grid_jit(jax.default_backend() != "cpu")
    args = (jnp.asarray(stack), jnp.asarray(ids), nxt,
            jnp.asarray(cost_matrix, dtype=jnp.float32), s,
            jnp.asarray(budgets, dtype=jnp.int32))
    up = _resolve_use_pallas(use_pallas)
    if profile is None:
        out = fn(*args, n, up)
    else:
        t0 = time.perf_counter()
        compiled = fn.lower(*args, n, up).compile()
        t1 = time.perf_counter()
        out = jax.block_until_ready(compiled(*args))
        t2 = time.perf_counter()
        profile.update(compile_s=t1 - t0, execute_s=t2 - t1,
                       cells=int(out.size))
    out = np.asarray(out)
    return out[0] if single else out
