"""Request traces: container + synthetic generators.

A trace is (ids, sizes): `ids[t]` is the object requested at step t;
`sizes[i]` the byte size of object i. The container is offline, so the
paper's real arms (Twitter twemcache cluster-52, Wikipedia CDN) are
represented by statistics-matched synthetic stand-ins (see DESIGN.md §7):

- `twemcache_like`: Zipf(alpha~1.0) popularity over small objects,
  log-normal sizes with mean ~243 B (paper Table 1 trace stats).
- `wiki_cdn_like`: heavy-tailed sizes (mean ~37 KB, max ~94 MB), a
  one-hit-wonder tail covering about half the objects (paper Fig. 4 notes).
- `zipf_trace`: the paper's synthetic arm — Zipf popularity assigned
  independently of size, so cheap-hot vs expensive-cold tension exists.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Trace", "zipf_trace", "twemcache_like", "wiki_cdn_like", "two_class_trace"]


@dataclasses.dataclass(frozen=True)
class Trace:
    """A request stream over a fixed object universe."""

    ids: np.ndarray    # (T,) int32 — object requested at each step
    sizes: np.ndarray  # (N,) float64 — object sizes in bytes
    name: str = "trace"

    @property
    def num_requests(self) -> int:
        return int(self.ids.shape[0])

    @property
    def num_objects(self) -> int:
        return int(self.sizes.shape[0])

    def access_sizes(self) -> np.ndarray:
        return self.sizes[self.ids]

    def reuse_fraction(self) -> float:
        """Fraction of requests that are re-accesses (upper bound on any hit rate)."""
        first = np.zeros(self.num_objects, bool)
        reuse = 0
        for i in self.ids:
            if first[i]:
                reuse += 1
            first[i] = True
        return reuse / max(1, self.num_requests)


def _zipf_ids(rng: np.random.Generator, n_objects: int, n_requests: int,
              alpha: float) -> np.ndarray:
    ranks = np.arange(1, n_objects + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(n_objects, size=n_requests, p=p).astype(np.int32)


def zipf_trace(n_objects: int = 500, n_requests: int = 5000, alpha: float = 0.9,
               size_dist: str = "lognormal", mean_size: float = 64 * 1024,
               sigma: float = 2.0, seed: int = 0, name: str = "zipf") -> Trace:
    """Paper's synthetic arm: Zipf popularity independent of size."""
    rng = np.random.default_rng(seed)
    ids = _zipf_ids(rng, n_objects, n_requests, alpha)
    if size_dist == "lognormal":
        # lognormal with the requested mean: mean = exp(mu + sigma^2/2)
        mu = np.log(mean_size) - sigma ** 2 / 2
        sizes = rng.lognormal(mu, sigma, size=n_objects)
    elif size_dist == "uniform":
        sizes = rng.uniform(1.0, 2 * mean_size, size=n_objects)
    else:
        raise ValueError(f"unknown size_dist {size_dist!r}")
    # shuffle sizes so popularity rank is independent of size
    rng.shuffle(sizes)
    return Trace(ids=ids, sizes=np.maximum(sizes, 1.0), name=name)


def two_class_trace(n_cheap: int = 50, n_exp: int = 20, n_requests: int = 4000,
                    cheap_size: float = 1024.0, exp_size: float = 1 << 30,
                    hot_fraction: float = 0.8, seed: int = 0) -> Trace:
    """Cheap-hot vs expensive-cold two-class workload (paper §1 example,
    used by the contention-frontier experiment §4/Fig. 2)."""
    rng = np.random.default_rng(seed)
    n = n_cheap + n_exp
    p = np.concatenate([
        np.full(n_cheap, hot_fraction / n_cheap),
        np.full(n_exp, (1 - hot_fraction) / n_exp),
    ])
    ids = rng.choice(n, size=n_requests, p=p).astype(np.int32)
    sizes = np.concatenate([np.full(n_cheap, cheap_size), np.full(n_exp, exp_size)])
    return Trace(ids=ids, sizes=sizes, name="two_class")


def twemcache_like(n_objects: int = 2000, n_requests: int = 20000,
                   seed: int = 0) -> Trace:
    """Twitter twemcache cluster-52 stand-in: small objects, mean ~243 B
    (narrow lognormal — memcache values cluster tightly in size)."""
    rng = np.random.default_rng(seed)
    ids = _zipf_ids(rng, n_objects, n_requests, alpha=1.0)
    sizes = rng.lognormal(np.log(200.0), 0.8, size=n_objects)
    sizes = np.clip(sizes, 16.0, 16 * 1024.0)
    sizes *= 243.0 / sizes[ids].mean()  # match *access-weighted* mean like the paper
    return Trace(ids=ids, sizes=np.maximum(sizes, 1.0), name="twemcache_like")


def wiki_cdn_like(n_objects: int = 6000, n_requests: int = 20000,
                  seed: int = 0) -> Trace:
    """Wikipedia CDN stand-in: mean ~37 KB, max ~94 MB, one-hit-wonder tail.

    Calibrated (pareto a=1.0, 55% one-hit tail) to land the paper's H=12-18
    band under egress-dominated pricing with low reuse — the largest
    objects are disproportionately single-touch (paper Fig. 4 caveats).
    """
    rng = np.random.default_rng(seed)
    # heavy-tail sizes: pareto body + a few huge objects
    sizes = (rng.pareto(1.0, size=n_objects) + 1.0) * 2048.0
    sizes = np.clip(sizes, 256.0, 94e6)
    order = np.argsort(sizes)  # sizes[order] ascending
    # popular core = smaller objects; one-hit tail = the rest (biggest last)
    n_core = int(n_objects * 0.45)
    core_ids = order[:n_core]
    tail_ids = order[n_core:]
    n_tail_req = min(len(tail_ids), n_requests // 3)
    core_req = _zipf_ids(rng, n_core, n_requests - n_tail_req, alpha=0.85)
    parts = [core_ids[core_req].astype(np.int32)]
    # each sampled tail object appears exactly once -> one-hit wonders
    parts.append(rng.choice(tail_ids, size=n_tail_req, replace=False).astype(np.int32))
    ids = np.concatenate(parts)
    rng.shuffle(ids)
    sizes = sizes * (37e3 / sizes[ids].mean())
    sizes = np.clip(sizes, 64.0, 94e6)
    return Trace(ids=ids, sizes=np.maximum(sizes, 1.0), name="wiki_cdn_like")


def next_use_indices(ids: np.ndarray, n_objects: int | None = None) -> np.ndarray:
    """next(t): index of the next request of the same object, or T if none.

    Reference (numpy) implementation; the Pallas kernel `kernels/next_use`
    mirrors it and is verified against this in tests. Vectorized: a stable
    sort groups each object's accesses in time order, so the successor
    within a group IS the next use.
    """
    ids = np.asarray(ids)
    T = ids.shape[0]
    if T == 0:
        return np.zeros(0, dtype=np.int64)
    order = np.argsort(ids, kind="stable")      # time order within each id
    sorted_ids = ids[order]
    succ = np.full(T, T, dtype=np.int64)
    same = sorted_ids[1:] == sorted_ids[:-1]
    succ[:-1][same] = order[1:][same]
    nxt = np.empty(T, dtype=np.int64)
    nxt[order] = succ
    return nxt
