# The paper's primary contribution: an exact offline dollar-optimal
# reference for cloud-egress caching, the cost-FOO bracket for variable
# sizes, dollar-scored policies, and the s* = f/e crossover.
from .pricing import (PRICE_VECTORS, PriceVector, crossover_bytes,
                      heterogeneity, miss_costs)
from .trace import (Trace, next_use_indices, twemcache_like, two_class_trace,
                    wiki_cdn_like, zipf_trace)
from .policies import POLICIES, PolicyResult, simulate, total_cost_no_cache
from .opt_exact import (OptResult, SweepResult, build_interval_arrays,
                        build_intervals, dp_opt_uniform, enumerate_opt_uniform,
                        exact_opt_uniform, exact_opt_uniform_sweep,
                        interval_deltas, lp_opt, zcap_profile)
from .cost_foo import (CostFooResult, cost_foo, round_fractional,
                       round_fractional_reference)
from .regret import regret, regret_table

__all__ = [
    "PRICE_VECTORS", "PriceVector", "crossover_bytes", "heterogeneity",
    "miss_costs", "Trace", "next_use_indices", "twemcache_like",
    "two_class_trace", "wiki_cdn_like", "zipf_trace", "POLICIES",
    "PolicyResult", "simulate", "total_cost_no_cache", "OptResult",
    "SweepResult", "build_interval_arrays", "build_intervals",
    "dp_opt_uniform", "enumerate_opt_uniform", "exact_opt_uniform",
    "exact_opt_uniform_sweep", "interval_deltas", "lp_opt", "zcap_profile",
    "CostFooResult", "cost_foo", "round_fractional",
    "round_fractional_reference", "regret", "regret_table",
]
