"""Dollar-regret against the exact (or bracketed) offline optimum.

R(pi) = (Cost(pi) - Cost(OPT)) / Cost(OPT)        (paper §2)
"""
from __future__ import annotations

import numpy as np

from . import policies as pol
from .opt_exact import exact_opt_uniform
from .trace import Trace

__all__ = ["regret", "regret_table"]


def regret(policy_dollars: float, opt_dollars: float) -> float:
    return (policy_dollars - opt_dollars) / max(opt_dollars, 1e-12)


def regret_table(trace: Trace, costs: np.ndarray, B: int,
                 policies: tuple[str, ...] = ("lru", "lfu", "gds", "gdsf",
                                              "belady", "cost_belady"),
                 ) -> dict[str, float]:
    """Uniform-size (page) regret table against the exact optimum."""
    opt = exact_opt_uniform(trace.ids, costs, B)
    out = {"opt_dollars": opt.dollars}
    for p in policies:
        r = pol.simulate(p, trace, costs, float(B))
        out[p] = regret(r.dollars, opt.dollars)
        out[f"{p}_dollars"] = r.dollars
    return out
