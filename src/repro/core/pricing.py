"""Cloud price vectors, per-miss dollar costs, the s* crossover and heterogeneity H.

Implements eq. (1)  c_i = f + s_i * e  (GET fee + egress), eq. (3)  s* = f / e,
and the access-weighted coefficient of variation H used by the
heterogeneity-regret law (paper §4).

Price vectors are list prices as of the paper (June 2026), dollars:
  f : per-GET request fee          [$ / request]
  e : per-byte egress rate         [$ / byte]
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

__all__ = [
    "PriceVector",
    "PRICE_VECTORS",
    "miss_costs",
    "crossover_bytes",
    "heterogeneity",
]


@dataclasses.dataclass(frozen=True)
class PriceVector:
    """A cloud billing vector: flat GET fee + linear egress rate."""

    name: str
    get_fee: float          # $ per GET request
    egress_per_byte: float  # $ per byte of egress
    latency_penalty: float = 0.0  # optional $-equivalent per miss (paper's "+ latency")

    def miss_cost(self, size_bytes) -> np.ndarray:
        """c_i = f + s_i * e (+ latency penalty). Vectorized over sizes."""
        s = np.asarray(size_bytes, dtype=np.float64)
        return self.get_fee + s * self.egress_per_byte + self.latency_penalty

    def miss_cost_scalar(self, size_bytes: float) -> float:
        """Scalar fast path for per-access hot loops (EgressCache, tracer
        spans): identical IEEE-754 operation order to `miss_cost`, so the
        result is bit-equal to `float(miss_cost(s))` — billing-faithful
        without the ~2us numpy round-trip."""
        return (self.get_fee + float(size_bytes) * self.egress_per_byte
                + self.latency_penalty)

    @property
    def crossover_bytes(self) -> float:
        """s* = f / e — object size at which GET fee equals egress cost."""
        return self.get_fee / self.egress_per_byte


# List prices (June 2026). GET fees are per-request; egress converted from $/GB.
_GB = 1e9
PRICE_VECTORS: Mapping[str, PriceVector] = {
    # S3 GET $0.40 per 1M requests, internet egress $0.09/GB  -> s* ~ 4.44 KB
    "s3_internet": PriceVector("s3_internet", 0.40e-6, 0.09 / _GB),
    # S3 cross-region transfer $0.02/GB -> s* ~ 20 KB
    "s3_cross_region": PriceVector("s3_cross_region", 0.40e-6, 0.02 / _GB),
    # GCS class-B op $0.40/1M ... but paper lists s* ~ 333 B via $0.004/10k GET
    # and $0.12/GB egress: f = 0.004/1e4 = 4.0e-8?  The paper's s* ~ 330 B with
    # e = $0.12/GB implies f = 4.0e-8 $/GET ($0.04 per 1M). Use that.
    "gcs_internet": PriceVector("gcs_internet", 0.04e-6, 0.12 / _GB),
    # Azure read ops ~$0.004 per 10k ($0.04/1M = 4.0e-8) with $0.087/GB -> ~460 B
    "azure_internet": PriceVector("azure_internet", 0.04e-6, 0.087 / _GB),
}


def miss_costs(sizes: np.ndarray, price: PriceVector) -> np.ndarray:
    """Per-object miss-cost vector c_i = f + s_i e."""
    return price.miss_cost(sizes)


def crossover_bytes(price: PriceVector) -> float:
    """Eq. (3): the GET-fee / egress crossover size s* = f/e."""
    return price.crossover_bytes


def heterogeneity(trace_ids: np.ndarray, costs_per_object: np.ndarray) -> float:
    """Access-weighted coefficient of variation H of the miss-cost vector.

    Each *access* contributes its object's miss cost; H = std/mean over the
    per-access cost sequence (paper §4: "access-weighted coefficient of
    variation of the miss-cost vector").
    """
    per_access = np.asarray(costs_per_object, dtype=np.float64)[np.asarray(trace_ids)]
    m = per_access.mean()
    if m == 0:
        return 0.0
    return float(per_access.std() / m)
