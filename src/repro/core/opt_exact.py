"""The exact offline dollar-optimum (paper §2).

For each request t whose object recurs at next(t), a binary x_t decides
whether the object is retained across the gap (a hit at next(t), saving
c_{o(t)}), occupying capacity at every *interior* serving instant:

    s_{o(tau)} + sum_{t < tau < next(t)} s_{o(t)} x_t  <=  B      (eq. 2)

Uniform sizes -> consecutive-ones constraint matrix -> totally unimodular ->
the LP relaxation is integral, and the optimum equals a min-cost flow on the
time line: shelf arcs of capacity B-1 and one unit-capacity arc per reuse
gap with cost -c_i.

This module provides three mutually-validating solvers:

  * `exact_opt_uniform`    — successive-shortest-path min-cost flow
                             (paper's scalable exact form; pure numpy/heapq)
  * `lp_opt`               — the interval LP in an O(T)-nonzero difference
                             form, solved by scipy/HiGHS (covers variable
                             sizes too, where it is the cost-FOO *fractional*
                             lower bound)
  * `dp_opt_uniform`,
    `enumerate_opt_uniform`— brute-force oracles for tiny instances (tests)

Total billed cost of a schedule = sum_t c_{o(t)}  -  savings(selected hits).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .trace import next_use_indices

__all__ = [
    "Interval",
    "build_intervals",
    "OptResult",
    "exact_opt_uniform",
    "lp_opt",
    "dp_opt_uniform",
    "enumerate_opt_uniform",
]


@dataclasses.dataclass(frozen=True)
class Interval:
    t: int      # request index of this access
    u: int      # next access of the same object (u < T)
    obj: int    # object id
    save: float  # dollars saved if retained (c_obj)
    size: float  # bytes occupied while retained


def build_intervals(ids: np.ndarray, costs: np.ndarray,
                    sizes: np.ndarray) -> list[Interval]:
    """All reuse gaps (t, next(t)) with next(t) < T."""
    ids = np.asarray(ids)
    nxt = next_use_indices(ids)
    T = len(ids)
    out = []
    for t in range(T):
        u = int(nxt[t])
        if u < T:
            i = int(ids[t])
            out.append(Interval(t, u, i, float(costs[i]), float(sizes[i])))
    return out


@dataclasses.dataclass
class OptResult:
    dollars: float            # total billed cost under the optimum
    savings: float            # dollars saved vs caching nothing
    total_no_cache: float     # sum of all c_{o(t)}
    hits: int                 # number of retained gaps (incl. free ones)
    selected: list[Interval]  # retained gaps (excl. trivially-free ones)
    free_hits: int            # gaps with no interior instant (always kept)


# ---------------------------------------------------------------------------
# min-cost flow (successive shortest paths with Johnson potentials)
# ---------------------------------------------------------------------------

class _MCMF:
    """Min-cost max-flow on a DAG-ordered node line, float costs.

    Arc storage in paired-edge style: edge i and i^1 are duals.
    """

    def __init__(self, n: int):
        self.n = n
        self.head: list[list[int]] = [[] for _ in range(n)]
        self.to: list[int] = []
        self.cap: list[float] = []
        self.cost: list[float] = []

    def add(self, a: int, b: int, cap: float, cost: float) -> int:
        i = len(self.to)
        self.to.append(b); self.cap.append(cap); self.cost.append(cost)
        self.to.append(a); self.cap.append(0.0); self.cost.append(-cost)
        self.head[a].append(i)
        self.head[b].append(i + 1)
        return i

    def solve(self, s: int, t: int, maxflow: float, eps: float = 1e-12):
        """Send up to `maxflow` units s->t; stop once the shortest augmenting
        path has non-negative cost (further units would be zero-cost shelf
        traffic only). Returns (flow_sent_on_negative_paths, total_cost)."""
        n = self.n
        INF = float("inf")
        # initial potentials: single forward pass (graph arcs all go a < b)
        pot = [INF] * n
        pot[s] = 0.0
        for a in range(n):
            if pot[a] == INF:
                continue
            for i in self.head[a]:
                if self.cap[i] > eps:
                    b = self.to[i]
                    d = pot[a] + self.cost[i]
                    if d < pot[b] - 1e-15:
                        pot[b] = d
        sent, total = 0.0, 0.0
        while maxflow > eps:
            dist = [INF] * n
            par: list[int] = [-1] * n
            dist[s] = 0.0
            pq = [(0.0, s)]
            while pq:
                d, a = heapq.heappop(pq)
                if d > dist[a] + 1e-15:
                    continue
                if a == t:
                    break
                for i in self.head[a]:
                    if self.cap[i] <= eps:
                        continue
                    b = self.to[i]
                    nd = d + self.cost[i] + pot[a] - pot[b]
                    if nd < dist[b] - 1e-15:
                        dist[b] = nd
                        par[b] = i
                        heapq.heappush(pq, (nd, b))
            if dist[t] == INF:
                break
            path_cost = dist[t] + pot[t] - pot[s]
            if path_cost >= -eps:
                break  # no more negative (dollar-saving) paths
            dt = dist[t]
            for a in range(n):
                if dist[a] < INF:
                    # early sink-break leaves tentative labels; clamping by
                    # dist[sink] keeps reduced costs non-negative (Johnson)
                    pot[a] += min(dist[a], dt)
                else:
                    pot[a] += dt
            # bottleneck
            f = maxflow
            b = t
            while b != s:
                i = par[b]
                f = min(f, self.cap[i])
                b = self.to[i ^ 1]
            b = t
            while b != s:
                i = par[b]
                self.cap[i] -= f
                self.cap[i ^ 1] += f
                b = self.to[i ^ 1]
            sent += f
            total += f * path_cost
            maxflow -= f
        return sent, total


def exact_opt_uniform(ids: np.ndarray, costs: np.ndarray, B: int,
                      return_selected: bool = False) -> OptResult:
    """Exact dollar-optimum for uniform-size pages via min-cost flow.

    Nodes = serving instants 1..T-1 plus sink T; shelf arcs p->p+1 with
    capacity B-1 (cost 0); a unit arc (t+1)->u with cost -c_i per reuse gap.
    Gaps with no interior instant (u == t+1) are free and always retained.
    """
    ids = np.asarray(ids)
    T = len(ids)
    total = float(costs[ids].sum())
    if B < 1 or T == 0:
        return OptResult(total, 0.0, total, 0, [], 0)
    intervals = build_intervals(ids, costs, np.ones(max(1, ids.max() + 1)))
    free = [iv for iv in intervals if iv.u == iv.t + 1]
    paid = [iv for iv in intervals if iv.u > iv.t + 1]
    free_save = sum(iv.save for iv in free)
    k = B - 1
    if k == 0 or not paid:
        dollars = total - free_save
        return OptResult(dollars, free_save, total, len(free), [], len(free))
    # node numbering: instant p (1..T-1) -> index p-1 ; sink instant T -> T-1
    n = T
    g = _MCMF(n)
    for p in range(1, T):  # shelf arc across every position cut p=1..T-1
        g.add(p - 1, p, float(k), 0.0)
    arc_of = {}
    for j, iv in enumerate(paid):
        # interval occupies instants t+1..u-1 -> arc node(t+1) -> node(u)
        arc_of[j] = g.add(iv.t, iv.u - 1, 1.0, -iv.save)
    _, cost = g.solve(0, T - 1, float(k))
    savings = -cost + free_save
    selected = []
    if return_selected:
        for j, iv in enumerate(paid):
            if g.cap[arc_of[j]] < 0.5:  # unit arc saturated
                selected.append(iv)
    dollars = total - savings
    return OptResult(dollars, savings, total,
                     len(free) + sum(1 for j in arc_of if g.cap[arc_of[j]] < 0.5),
                     selected, len(free))


# ---------------------------------------------------------------------------
# sparse interval LP (difference form) — uniform exact / variable fractional
# ---------------------------------------------------------------------------

def lp_opt(ids: np.ndarray, costs: np.ndarray, sizes: np.ndarray, B: float):
    """Interval LP (eq. 2) in an O(T + m)-nonzero difference form via HiGHS.

    Returns (dollars_lower_bound, savings_upper_bound, x_fractional, paid).
    For uniform sizes the matrix is totally unimodular: x is integral and the
    bound is the exact optimum. For variable sizes this is the cost-FOO
    fractional lower bound on billed dollars.

    Difference form: occupancy z_tau (tau = 1..T-1) with
        z_1 = sum_{t=0} s_i x_i ;  z_tau - z_{tau-1} = starts(tau-1) - ends(tau)
        0 <= z_tau <= B - s_{o(tau)}   (B if s_{o(tau)} > B: fetch-through)
    which has 2 nonzeros per x and per z instead of one per covered instant.
    """
    from scipy import sparse
    from scipy.optimize import linprog

    ids = np.asarray(ids)
    T = len(ids)
    total = float(costs[ids].sum())
    intervals = build_intervals(ids, costs, sizes)
    free_save = sum(iv.save for iv in intervals
                    if iv.u == iv.t + 1 and iv.size <= B)
    paid = [iv for iv in intervals if iv.u > iv.t + 1 and iv.size <= B]
    m = len(paid)
    nz = T - 1  # number of occupancy variables z_1..z_{T-1}
    if m == 0 or nz <= 0:
        return total - free_save, free_save, np.zeros(0), paid
    # conditioning: cloud miss costs are ~1e-8 $ (below HiGHS's default
    # tolerances) and sizes span bytes..GB — normalize both scales
    save_scale = float(np.mean([iv.save for iv in paid])) or 1.0
    size_scale = float(np.mean([iv.size for iv in paid])) or 1.0
    rows, cols, vals = [], [], []
    # z coefficients: +1 in row tau, -1 in row tau+1  (rows are 0-indexed tau-1)
    for tau in range(1, T):      # tau = 1..T-1 ; row index tau-1
        rows.append(tau - 1); cols.append(m + tau - 1); vals.append(1.0)
        if tau + 1 <= T - 1:
            rows.append(tau); cols.append(m + tau - 1); vals.append(-1.0)
    # x coefficients: interval occupies instants t+1..u-1
    for j, iv in enumerate(paid):
        rows.append(iv.t + 1 - 1); cols.append(j); vals.append(-iv.size / size_scale)
        if iv.u <= T - 1:        # stops occupying at instant u
            rows.append(iv.u - 1); cols.append(j); vals.append(iv.size / size_scale)
    A = sparse.csc_matrix((vals, (rows, cols)), shape=(nz, m + nz))
    b_eq = np.zeros(nz)
    c = np.concatenate([-np.array([iv.save / save_scale for iv in paid]),
                        np.zeros(nz)])
    zcap = np.array([max(B - sizes[ids[tau]], 0.0) if sizes[ids[tau]] <= B else B
                     for tau in range(1, T)]) / size_scale
    bounds = [(0.0, 1.0)] * m + [(0.0, float(zc)) for zc in zcap]
    res = linprog(c, A_eq=A, b_eq=b_eq, bounds=bounds, method="highs")
    if not res.success:
        raise RuntimeError(f"LP failed: {res.message}")
    x = res.x[:m]
    savings = float(-res.fun) * save_scale + free_save
    return total - savings, savings, x, paid


# ---------------------------------------------------------------------------
# brute-force oracles (tests only)
# ---------------------------------------------------------------------------

def enumerate_opt_uniform(ids: np.ndarray, costs: np.ndarray, B: int) -> float:
    """Exhaustive subset enumeration over reuse gaps (validates eq. 2 itself).
    Only for #paid intervals <= ~18."""
    ids = np.asarray(ids)
    T = len(ids)
    total = float(costs[ids].sum())
    intervals = build_intervals(ids, costs, np.ones(max(1, ids.max() + 1)))
    free_save = sum(iv.save for iv in intervals if iv.u == iv.t + 1)
    paid = [iv for iv in intervals if iv.u > iv.t + 1]
    m = len(paid)
    assert m <= 20, "too many intervals to enumerate"
    best = 0.0
    for mask in range(1 << m):
        occ = np.zeros(T, dtype=np.int64)
        save = 0.0
        ok = True
        for j in range(m):
            if mask >> j & 1:
                iv = paid[j]
                occ[iv.t + 1:iv.u] += 1
                save += iv.save
        if B >= 1 and (occ > B - 1).any():
            ok = False
        if ok:
            best = max(best, save)
    return total - (best + free_save)


def dp_opt_uniform(ids: np.ndarray, costs: np.ndarray, B: int) -> float:
    """State-space DP over cache contents — validates that eq. (2) models
    real caching (independent of the interval formulation). Tiny inputs only.

    Semantics match eq. (2): the object being served always occupies a slot
    at its serving instant (no bypass), so a miss on a full cache must evict
    one resident even if the fetched object is then dropped immediately.
    """
    ids = np.asarray(ids)
    states: dict[frozenset, float] = {frozenset(): 0.0}
    for t, i in enumerate(ids):
        i = int(i)
        new: dict[frozenset, float] = {}

        def upd(st, c):
            if st not in new or c < new[st]:
                new[st] = c

        for st, c in states.items():
            if i in st:
                upd(st, c)  # hit
                continue
            c2 = c + float(costs[i])
            S = set(st)
            if len(S) < B:
                upd(frozenset(S | {i}), c2)  # retain the fetched object
                upd(frozenset(S), c2)        # drop it right after serving
            else:
                # full: serving displaces one resident no matter what
                for v in S:
                    upd(frozenset((S - {v}) | {i}), c2)
                    upd(frozenset(S - {v}), c2)
        states = new
    return min(states.values())
