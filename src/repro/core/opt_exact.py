"""The exact offline dollar-optimum (paper §2).

For each request t whose object recurs at next(t), a binary x_t decides
whether the object is retained across the gap (a hit at next(t), saving
c_{o(t)}), occupying capacity at every *interior* serving instant:

    s_{o(tau)} + sum_{t < tau < next(t)} s_{o(t)} x_t  <=  B      (eq. 2)

Uniform sizes -> consecutive-ones constraint matrix -> totally unimodular ->
the LP relaxation is integral, and the optimum equals a min-cost flow on the
time line: shelf arcs of capacity B-1 and one unit-capacity arc per reuse
gap with cost -c_i.

This module provides three mutually-validating solvers:

  * `exact_opt_uniform`    — successive-shortest-path min-cost flow on flat
                             CSR numpy arrays, shortest paths by scipy's C
                             Dijkstra (paper's scalable exact form)
  * `exact_opt_uniform_sweep`
                           — the parametric form: ONE warm-started SSP run
                             answers every budget in a grid at roughly the
                             cost of the largest single solve (DESIGN.md §5)
  * `lp_opt`               — the interval LP in an O(T)-nonzero difference
                             form, solved by scipy/HiGHS (covers variable
                             sizes too, where it is the cost-FOO *fractional*
                             lower bound)
  * `dp_opt_uniform`,
    `enumerate_opt_uniform`— brute-force oracles for tiny instances (tests)

Total billed cost of a schedule = sum_t c_{o(t)}  -  savings(selected hits).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .trace import next_use_indices

__all__ = [
    "Interval",
    "build_intervals",
    "build_interval_arrays",
    "interval_deltas",
    "zcap_profile",
    "OptResult",
    "SweepResult",
    "exact_opt_uniform",
    "exact_opt_uniform_sweep",
    "lp_opt",
    "lp_solve_arrays",
    "dp_opt_uniform",
    "enumerate_opt_uniform",
]


@dataclasses.dataclass(frozen=True)
class Interval:
    t: int      # request index of this access
    u: int      # next access of the same object (u < T)
    obj: int    # object id
    save: float  # dollars saved if retained (c_obj)
    size: float  # bytes occupied while retained


def build_interval_arrays(ids: np.ndarray, costs: np.ndarray,
                          sizes: np.ndarray):
    """Vectorized reuse-gap extraction: flat (t, u, obj, save, size) arrays.

    The array form of `build_intervals` — shared by the LP's difference-form
    matrix construction, the epoch decomposition in `cost_foo`, and
    `interval_deltas` (the occupancy kernel's input). One `next_use_indices`
    pass plus boolean masks instead of a Python loop over T.
    """
    ids = np.asarray(ids)
    nxt = next_use_indices(ids)
    T = len(ids)
    keep = nxt < T
    t = np.flatnonzero(keep).astype(np.int64)
    u = nxt[keep].astype(np.int64)
    obj = ids[keep].astype(np.int64)
    save = np.asarray(costs, np.float64)[obj]
    size = np.asarray(sizes, np.float64)[obj]
    return t, u, obj, save, size


def build_intervals(ids: np.ndarray, costs: np.ndarray,
                    sizes: np.ndarray) -> list[Interval]:
    """All reuse gaps (t, next(t)) with next(t) < T."""
    t, u, obj, save, size = build_interval_arrays(ids, costs, sizes)
    return [Interval(a, b, o, sv, sz)
            for a, b, o, sv, sz in zip(t.tolist(), u.tolist(), obj.tolist(),
                                       save.tolist(), size.tolist())]


def interval_deltas(t: np.ndarray, u: np.ndarray, size: np.ndarray,
                    T: int) -> np.ndarray:
    """Per-instant occupancy deltas of a retention schedule.

    Interval (t, u) occupies serving instants t+1..u-1, so it contributes
    +size at index t+1 and -size at index u; the prefix sum of the result
    is eq. (2)'s LHS occupancy profile — feed it to
    `kernels.interval_occupancy` / `kernels.occupancy_feasible`.
    """
    d = np.zeros(int(T), np.float64)
    t = np.asarray(t, np.int64)
    u = np.asarray(u, np.int64)
    size = np.asarray(size, np.float64)
    starts = t + 1
    sm = starts < T
    np.add.at(d, starts[sm], size[sm])
    em = u < T
    np.add.at(d, u[em], -size[em])
    return d


def zcap_profile(ids: np.ndarray, sizes: np.ndarray, B: float) -> np.ndarray:
    """Occupancy cap per serving instant (eq. 2's RHS), vectorized.

    zcap[tau] = B - s_{o(tau)} while the served object fits, else B
    (fetch-through: an over-budget object never occupies the cache).
    Index 0 is a placeholder set to B — there is no constraint before the
    first request.
    """
    ids = np.asarray(ids)
    s_at = np.asarray(sizes, np.float64)[ids]
    zcap = np.where(s_at <= B, B - s_at, float(B))
    if len(zcap):
        zcap[0] = float(B)
    return zcap


@dataclasses.dataclass
class OptResult:
    dollars: float            # total billed cost under the optimum
    savings: float            # dollars saved vs caching nothing
    total_no_cache: float     # sum of all c_{o(t)}
    hits: int                 # number of retained gaps (incl. free ones)
    selected: list[Interval]  # retained gaps (excl. trivially-free ones)
    free_hits: int            # gaps with no interior instant (always kept)
    profile: dict = dataclasses.field(default_factory=dict)  # solver counters


# ---------------------------------------------------------------------------
# min-cost flow (successive shortest paths with Johnson potentials)
#
# Flat-array engine: arcs live in paired numpy arrays (edge i and i^1 are
# duals), adjacency is CSR-style (edges lexsorted by (src, dst), grouped),
# and each shortest-path phase runs through scipy's C Dijkstra on reduced
# costs. Saturated arcs are not removed from the CSR structure — their
# weight is set to _BLOCKED, far above any real path cost, so the sparsity
# pattern (and the per-(src,dst) dedup below) is computed exactly once.
# ---------------------------------------------------------------------------

_BLOCKED = 1e18          # weight of a saturated arc in the Dijkstra graph
_BLOCK_THRESH = 1e17     # any dist above this means "no residual path"


@dataclasses.dataclass
class SweepResult:
    """Exact OPT for every budget in a grid, from ONE parametric SSP run."""
    budgets: np.ndarray        # (K,) int   — page budgets B
    dollars: np.ndarray        # (K,) float — exact billed cost at each B
    savings: np.ndarray        # (K,) float — dollars saved vs caching nothing
    hits: np.ndarray           # (K,) int   — retained gaps (incl. free ones)
    total_no_cache: float      # sum of all c_{o(t)}
    free_hits: int             # gaps with no interior instant (always kept)
    unit_path_costs: np.ndarray  # per-unit SSP path costs (non-decreasing)
    profile: dict = dataclasses.field(default_factory=dict)  # solver counters


class _ParametricSSP:
    """Successive shortest paths on the caching time line, budget-parametric.

    Nodes are serving instants 1..T-1 (index p-1) plus the sink instant T
    (index T-1); shelf arcs (p-1 -> p, capacity k_max, cost 0) and one unit
    arc per paid reuse gap (node t -> node u-1, cost -save).

    Why one run answers every budget: with flow value bounded by k, the flow
    through any shelf arc is at most k (every cut carries exactly the total
    flow, and interval arcs take their share first), so the shelf capacity
    never binds and the ONLY budget-dependent quantity is the flow bound
    itself. SSP augments along non-decreasing path costs, hence the optimal
    flow of value k is, for every k, a prefix of the same augmentation
    sequence — raising the budget just unlocks the next units. Recording the
    per-unit path costs therefore yields exact OPT for all budgets at once.
    """

    def __init__(self, T: int, paid_t: np.ndarray, paid_u: np.ndarray,
                 paid_save: np.ndarray, k_max: int):
        # profiling counters (DESIGN.md §9): surfaced via OptResult/
        # SweepResult `.profile` so operators can see where solve time went
        self.dijkstra_calls = 0
        self.augmentations = 0
        self.n = n = T
        self.s, self.t = 0, T - 1
        self.m = m = len(paid_t)
        self.eps = 1e-12 * max(1.0, float(paid_save.max()) if m else 1.0)
        ns = T - 1  # shelf arcs
        ne = 2 * (ns + m)
        shelf_src = np.arange(ns, dtype=np.int64)
        fwd_src = np.concatenate([shelf_src, paid_t.astype(np.int64)])
        fwd_dst = np.concatenate([shelf_src + 1, paid_u.astype(np.int64) - 1])
        fwd_cap = np.concatenate([np.full(ns, float(k_max)), np.ones(m)])
        fwd_cost = np.concatenate([np.zeros(ns), -paid_save.astype(np.float64)])
        self.frm = np.empty(ne, np.int64)
        self.to = np.empty(ne, np.int64)
        self.cap = np.empty(ne, np.float64)
        self.cost = np.empty(ne, np.float64)
        self.frm[0::2] = fwd_src; self.to[0::2] = fwd_dst
        self.cap[0::2] = fwd_cap; self.cost[0::2] = fwd_cost
        self.frm[1::2] = fwd_dst; self.to[1::2] = fwd_src
        self.cap[1::2] = 0.0;     self.cost[1::2] = -fwd_cost
        self.first_interval_edge = 2 * ns  # interval fwd arcs: even ids >= this

        # CSR with per-(src,dst) dedup. Parallel arcs happen only when a gap
        # has exactly one interior instant (interval arc t -> t+1 alongside
        # the shelf arc), so every group has at most two members.
        order = np.lexsort((self.to, self.frm))
        key = self.frm[order] * np.int64(n) + self.to[order]
        first = np.ones(len(key), bool)
        first[1:] = key[1:] != key[:-1]
        starts = np.flatnonzero(first)
        sizes = np.diff(np.append(starts, len(key)))
        assert sizes.max(initial=1) <= 2, "unexpected arc multiplicity"
        self.grp_keys = key[starts]
        self.grp_e0 = order[starts]
        self.grp_e1 = np.where(sizes == 2, order[np.minimum(starts + 1,
                                                            len(key) - 1)], -1)
        src_of_grp = self.frm[self.grp_e0]
        counts = np.bincount(src_of_grp, minlength=n)
        self.indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
        self.indices = self.to[self.grp_e0].astype(np.int32)

        # exact initial potentials: one relaxation pass in topological order
        # (the original graph is a DAG on the time line)
        pot = np.zeros(n)
        by_dst = np.argsort(paid_u, kind="stable") if m else np.zeros(0, int)
        ptr = 0
        for p in range(1, n):
            lo = pot[p - 1]
            while ptr < m and int(paid_u[by_dst[ptr]]) - 1 == p:
                j = by_dst[ptr]
                cand = pot[int(paid_t[j])] - float(paid_save[j])
                if cand < lo:
                    lo = cand
                ptr += 1
            pot[p] = lo
        self.pot = pot

    def run(self, max_units: int) -> tuple[np.ndarray, np.ndarray]:
        """Augment unit-by-unit until `max_units` is reached or the shortest
        residual path stops saving dollars. Returns (unit_path_costs,
        unit_net_selected): per flow unit, its true path cost and the net
        number of interval arcs it newly saturates (reverse traversals of
        earlier selections count -1 — one unit can carry several short gaps
        or re-route earlier ones)."""
        from scipy.sparse import csr_matrix
        from scipy.sparse.csgraph import dijkstra

        n, s, t = self.n, self.s, self.t
        unit_costs: list[float] = []
        unit_dsel: list[int] = []
        remaining = max_units
        while remaining > 0:
            rc = self.cost + self.pot[self.frm] - self.pot[self.to]
            w = np.where(self.cap > 0.5, rc, _BLOCKED)
            data = w[self.grp_e0]
            two = self.grp_e1 >= 0
            np.minimum(data, np.where(two, w[self.grp_e1], _BLOCKED),
                       out=data)
            np.maximum(data, 0.0, out=data)  # clip fp jitter in reduced costs
            g = csr_matrix((data, self.indices, self.indptr), shape=(n, n))
            self.dijkstra_calls += 1
            dist, pred = dijkstra(g, directed=True, indices=s,
                                  return_predecessors=True)
            dt = float(dist[t])
            if dt >= _BLOCK_THRESH:
                break
            path_cost = dt + self.pot[t] - self.pot[s]
            if path_cost >= -self.eps:
                break  # no more negative (dollar-saving) paths
            self.pot += np.minimum(dist, dt)  # Johnson update, clamped at sink
            # node path sink -> source, then per-hop arc selection
            nodes = [t]
            b = t
            while b != s:
                b = int(pred[b])
                nodes.append(b)
            hops = np.array(nodes[::-1], dtype=np.int64)
            a_arr, b_arr = hops[:-1], hops[1:]
            gidx = np.searchsorted(self.grp_keys, a_arr * np.int64(n) + b_arr)
            e0 = self.grp_e0[gidx]
            e1 = self.grp_e1[gidx]
            use1 = (e1 >= 0) & (w[np.maximum(e1, 0)] < w[e0])
            edges = np.where(use1, e1, e0)
            f = min(float(remaining), float(self.cap[edges].min()))
            # a dollar-saving path always crosses a unit interval arc
            assert f == 1.0, f"non-unit bottleneck {f} on a negative path"
            self.cap[edges] -= f
            self.cap[edges ^ 1] += f
            is_iv = edges >= self.first_interval_edge
            dsel = int(np.sum(is_iv & (edges % 2 == 0))
                       - np.sum(is_iv & (edges % 2 == 1)))
            unit_costs.append(path_cost)
            unit_dsel.append(dsel)
            remaining -= 1
        self.augmentations += len(unit_costs)
        return np.asarray(unit_costs), np.asarray(unit_dsel, dtype=np.int64)

    def profile(self, budgets_answered: int = 1) -> dict:
        """Solver counters: how the exact answer was produced. A sweep
        answers `budgets_answered` budgets from this ONE augmentation
        sequence — that ratio is the warm-start reuse."""
        return dict(dijkstra_calls=self.dijkstra_calls,
                    augmentations=self.augmentations,
                    nodes=int(self.n), paid_intervals=int(self.m),
                    budgets_answered=int(budgets_answered),
                    warm_start_reuse=float(budgets_answered))

    def saturated_intervals(self) -> np.ndarray:
        """Indices j of paid intervals whose unit arc is saturated."""
        iv_caps = self.cap[self.first_interval_edge::2]
        return np.flatnonzero(iv_caps < 0.5)


def _paid_free_arrays(ids: np.ndarray, costs: np.ndarray):
    """Vectorized interval extraction: (paid_t, paid_u, paid_save, free_save,
    n_free, total)."""
    ids = np.asarray(ids)
    T = len(ids)
    save = np.asarray(costs, dtype=np.float64)[ids] if T else np.zeros(0)
    total = float(save.sum())
    nxt = next_use_indices(ids)
    t_arr = np.arange(T, dtype=np.int64)
    recurs = nxt < T
    free = recurs & (nxt == t_arr + 1)
    paid = recurs & (nxt > t_arr + 1)
    return (t_arr[paid], nxt[paid], save[paid],
            float(save[free].sum()), int(free.sum()), total)


def exact_opt_uniform(ids: np.ndarray, costs: np.ndarray, B: int,
                      return_selected: bool = False) -> OptResult:
    """Exact dollar-optimum for uniform-size pages via min-cost flow.

    Nodes = serving instants 1..T-1 plus sink T; shelf arcs p->p+1 with
    capacity B-1 (cost 0); a unit arc (t+1)->u with cost -c_i per reuse gap.
    Gaps with no interior instant (u == t+1) are free and always retained.
    """
    ids = np.asarray(ids)
    T = len(ids)
    if B < 1 or T == 0:
        total = float(np.asarray(costs)[ids].sum()) if T else 0.0
        return OptResult(total, 0.0, total, 0, [], 0)
    paid_t, paid_u, paid_save, free_save, n_free, total = \
        _paid_free_arrays(ids, costs)
    k = B - 1
    if k == 0 or len(paid_t) == 0:
        dollars = total - free_save
        return OptResult(dollars, free_save, total, n_free, [], n_free)
    ssp = _ParametricSSP(T, paid_t, paid_u, paid_save, k)
    unit_costs, _ = ssp.run(k)
    savings = float(-unit_costs.sum()) + free_save
    sel_idx = ssp.saturated_intervals()
    selected = []
    if return_selected:
        selected = [Interval(int(paid_t[j]), int(paid_u[j]), int(ids[paid_t[j]]),
                             float(paid_save[j]), 1.0) for j in sel_idx]
    dollars = total - savings
    return OptResult(dollars, savings, total, n_free + len(sel_idx),
                     selected, n_free, profile=ssp.profile())


def exact_opt_uniform_sweep(ids: np.ndarray, costs: np.ndarray,
                            budgets: np.ndarray) -> SweepResult:
    """Exact dollar-optimum for EVERY budget in `budgets`, one SSP run.

    Warm start along the budget axis: the residual graph after k units of
    flow is exactly the state a (k+1)-budget solve would resume from, so the
    sweep costs roughly one solve at max(budgets) instead of len(budgets)
    independent solves (see `_ParametricSSP` for why capacities never bind).

    Matches per-budget `exact_opt_uniform` to float precision; asserted at
    1e-6 relative in tests and bench_flow_scale.
    """
    budgets = np.asarray(budgets, dtype=np.int64)
    if budgets.ndim != 1 or len(budgets) == 0:
        raise ValueError("budgets must be a non-empty 1-D array")
    ids = np.asarray(ids)
    T = len(ids)
    K = len(budgets)
    paid_t, paid_u, paid_save, free_save, n_free, total = \
        _paid_free_arrays(ids, costs)
    k_max = int(budgets.max()) - 1
    if T == 0 or k_max < 1 or len(paid_t) == 0:
        unit_costs = np.zeros(0)
        unit_dsel = np.zeros(0, np.int64)
        profile = dict(dijkstra_calls=0, augmentations=0, nodes=int(T),
                       paid_intervals=int(len(paid_t)),
                       budgets_answered=int(K), warm_start_reuse=float(K))
    else:
        ssp = _ParametricSSP(T, paid_t, paid_u, paid_save, k_max)
        unit_costs, unit_dsel = ssp.run(k_max)
        profile = ssp.profile(budgets_answered=K)
    cum_save = np.concatenate([[0.0], np.cumsum(-unit_costs)])
    cum_sel = np.concatenate([[0], np.cumsum(unit_dsel)])
    ks = np.clip(budgets - 1, 0, len(unit_costs))
    alive = budgets >= 1  # B < 1 cannot even keep free (adjacent) repeats
    savings = np.where(alive, cum_save[ks] + free_save, 0.0)
    hits = np.where(alive, cum_sel[ks] + n_free, 0).astype(np.int64)
    return SweepResult(budgets=budgets, dollars=total - savings,
                       savings=savings, hits=hits, total_no_cache=total,
                       free_hits=n_free, unit_path_costs=unit_costs,
                       profile=profile)


# ---------------------------------------------------------------------------
# sparse interval LP (difference form) — uniform exact / variable fractional
# ---------------------------------------------------------------------------

def lp_solve_arrays(pt: np.ndarray, pu: np.ndarray, psave: np.ndarray,
                    psize: np.ndarray, zcap: np.ndarray, nz: int):
    """Difference-form interval LP (eq. 2's relaxation) over local instants.

    The array core behind `lp_opt` and `cost_foo`'s epoch decomposition:
    interval j occupies instants pt[j]+1..pu[j]-1 (1-based local instants,
    so 0 <= pt[j] and pu[j]-1 <= nz); zcap[k] caps occupancy at instant
    k+1 (length nz). Matrix construction is fully vectorized — 2 nonzeros
    per variable, assembled with numpy concatenates instead of per-row
    Python appends. Returns (savings_upper_bound, x_fractional).
    """
    from scipy import sparse
    from scipy.optimize import linprog

    m = len(pt)
    if m == 0 or nz <= 0:
        return 0.0, np.zeros(0)
    # conditioning: cloud miss costs are ~1e-8 $ (below HiGHS's default
    # tolerances) and sizes span bytes..GB — normalize both scales
    save_scale = float(psave.mean()) or 1.0
    size_scale = float(psize.mean()) or 1.0
    sz = psize / size_scale
    taus = np.arange(1, nz + 1, dtype=np.int64)
    # z coefficients: z_tau is +1 in row tau-1, -1 in row tau (tau <= nz-1);
    # x coefficients: -size in row t (starts occupying at instant t+1),
    # +size in row u-1 when it stops occupying inside the horizon
    ends = pu <= nz
    rows = np.concatenate([taus - 1, taus[:nz - 1],
                           pt, pu[ends] - 1])
    cols = np.concatenate([m + taus - 1, m + taus[:nz - 1] - 1,
                           np.arange(m, dtype=np.int64), np.flatnonzero(ends)])
    vals = np.concatenate([np.ones(nz), -np.ones(nz - 1), -sz, sz[ends]])
    A = sparse.csc_matrix((vals, (rows, cols)), shape=(nz, m + nz))
    c = np.concatenate([-psave / save_scale, np.zeros(nz)])
    zc = zcap / size_scale
    bounds = [(0.0, 1.0)] * m + list(zip(np.zeros(nz), zc))
    res = linprog(c, A_eq=A, b_eq=np.zeros(nz), bounds=bounds, method="highs")
    if not res.success:
        raise RuntimeError(f"LP failed: {res.message}")
    return float(-res.fun) * save_scale, res.x[:m]


def lp_opt(ids: np.ndarray, costs: np.ndarray, sizes: np.ndarray, B: float):
    """Interval LP (eq. 2) in an O(T + m)-nonzero difference form via HiGHS.

    Returns (dollars_lower_bound, savings_upper_bound, x_fractional, paid).
    For uniform sizes the matrix is totally unimodular: x is integral and the
    bound is the exact optimum. For variable sizes this is the cost-FOO
    fractional lower bound on billed dollars.

    Difference form: occupancy z_tau (tau = 1..T-1) with
        z_1 = sum_{t=0} s_i x_i ;  z_tau - z_{tau-1} = starts(tau-1) - ends(tau)
        0 <= z_tau <= B - s_{o(tau)}   (B if s_{o(tau)} > B: fetch-through)
    which has 2 nonzeros per x and per z instead of one per covered instant.
    """
    ids = np.asarray(ids)
    T = len(ids)
    costs = np.asarray(costs, np.float64)
    total = float(costs[ids].sum()) if T else 0.0
    t, u, obj, save, size = build_interval_arrays(ids, costs, sizes)
    fits = size <= B
    free_save = float(save[fits & (u == t + 1)].sum())
    paidm = fits & (u > t + 1)
    pt, pu = t[paidm], u[paidm]
    ps, pz = save[paidm], size[paidm]
    paid = [Interval(a, b, o, sv, szv)
            for a, b, o, sv, szv in zip(pt.tolist(), pu.tolist(),
                                        obj[paidm].tolist(), ps.tolist(),
                                        pz.tolist())]
    nz = T - 1
    if len(paid) == 0 or nz <= 0:
        return total - free_save, free_save, np.zeros(0), paid
    zcap = zcap_profile(ids, sizes, B)[1:]
    savings, x = lp_solve_arrays(pt, pu, ps, pz, zcap, nz)
    savings += free_save
    return total - savings, savings, x, paid


# ---------------------------------------------------------------------------
# brute-force oracles (tests only)
# ---------------------------------------------------------------------------

def enumerate_opt_uniform(ids: np.ndarray, costs: np.ndarray, B: int) -> float:
    """Exhaustive subset enumeration over reuse gaps (validates eq. 2 itself).
    Only for #paid intervals <= ~18."""
    ids = np.asarray(ids)
    T = len(ids)
    total = float(costs[ids].sum())
    intervals = build_intervals(ids, costs, np.ones(max(1, ids.max() + 1)))
    free_save = sum(iv.save for iv in intervals if iv.u == iv.t + 1)
    paid = [iv for iv in intervals if iv.u > iv.t + 1]
    m = len(paid)
    assert m <= 20, "too many intervals to enumerate"
    best = 0.0
    for mask in range(1 << m):
        occ = np.zeros(T, dtype=np.int64)
        save = 0.0
        ok = True
        for j in range(m):
            if mask >> j & 1:
                iv = paid[j]
                occ[iv.t + 1:iv.u] += 1
                save += iv.save
        if B >= 1 and (occ > B - 1).any():
            ok = False
        if ok:
            best = max(best, save)
    return total - (best + free_save)


def dp_opt_uniform(ids: np.ndarray, costs: np.ndarray, B: int) -> float:
    """State-space DP over cache contents — validates that eq. (2) models
    real caching (independent of the interval formulation). Tiny inputs only.

    Semantics match eq. (2): the object being served always occupies a slot
    at its serving instant (no bypass), so a miss on a full cache must evict
    one resident even if the fetched object is then dropped immediately.
    """
    ids = np.asarray(ids)
    states: dict[frozenset, float] = {frozenset(): 0.0}
    for t, i in enumerate(ids):
        i = int(i)
        new: dict[frozenset, float] = {}

        def upd(st, c):
            if st not in new or c < new[st]:
                new[st] = c

        for st, c in states.items():
            if i in st:
                upd(st, c)  # hit
                continue
            c2 = c + float(costs[i])
            S = set(st)
            if len(S) < B:
                upd(frozenset(S | {i}), c2)  # retain the fetched object
                upd(frozenset(S), c2)        # drop it right after serving
            else:
                # full: serving displaces one resident no matter what
                for v in S:
                    upd(frozenset((S - {v}) | {i}), c2)
                    upd(frozenset(S - {v}), c2)
        states = new
    return min(states.values())
