"""cost-FOO: flow/LP bracket on the dollar-optimum for variable sizes (paper §2).

General caching with variable sizes is NP-hard (Folwarczny & Sgall 2015).
The LP relaxation of the interval program (eq. 2) is a *fractional-caching
lower bound* on billed dollars — the dollar analogue of FOO (Berger et al.
2018). A feasible schedule upper-brackets the optimum. The pair is cost-FOO;
the paper reports a median bracket (U-L)/L of ~0.04 on synthetic traces.

  L = lp_opt(...)                         (fractional, via sparse HiGHS LP)
  U = min( greedy rounding of the LP x ,  best feasible policy in dollars )
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import policies as pol
from .opt_exact import Interval, lp_opt
from .trace import Trace

__all__ = ["CostFooResult", "cost_foo", "round_fractional"]


@dataclasses.dataclass
class CostFooResult:
    lower: float            # LP fractional lower bound on billed dollars
    upper: float            # best feasible schedule, billed dollars
    total_no_cache: float
    bracket: float          # (U - L) / L

    @property
    def is_tight(self) -> bool:
        return self.bracket <= 0.05


def _occupancy_feasible(sel: list[Interval], extra: Interval, occ: np.ndarray,
                        zcap: np.ndarray) -> bool:
    """Would adding `extra` keep occupancy within B - s_{o(tau)} everywhere?"""
    a, b = extra.t + 1, extra.u - 1
    if a > b:
        return True
    seg = occ[a:b + 1] + extra.size
    return bool((seg <= zcap[a:b + 1] + 1e-9).all())


def round_fractional(ids: np.ndarray, sizes: np.ndarray, B: float,
                     x: np.ndarray, paid: list[Interval]) -> float:
    """PFOO-like rounding: greedily retain gaps by LP preference (x, then
    dollar density), keeping the occupancy profile feasible. Returns the
    dollars *saved* by the resulting feasible schedule."""
    T = len(ids)
    # z-cap per instant tau=1..T-1 (index tau); instant 0 unused
    zcap = np.zeros(T)
    for tau in range(1, T):
        s = sizes[ids[tau]]
        zcap[tau] = B - s if s <= B else B
    occ = np.zeros(T)
    order = sorted(range(len(paid)),
                   key=lambda j: (-float(x[j] > 0.999),
                                  -float(x[j]) * paid[j].save / max(paid[j].size, 1.0)))
    saved = 0.0
    for j in order:
        iv = paid[j]
        if x[j] <= 1e-9:
            continue
        if _occupancy_feasible([], iv, occ, zcap):
            occ[iv.t + 1:iv.u] += iv.size
            saved += iv.save
    return saved


def cost_foo(trace: Trace, costs: np.ndarray, B: float,
             policies: tuple[str, ...] = ("gdsf", "gds", "cost_belady", "belady"),
             ) -> CostFooResult:
    total = float(costs[trace.ids].sum())
    lower, savings_ub, x, paid = lp_opt(trace.ids, costs, trace.sizes, B)
    # free savings (u == t+1) are already inside `lower`; recompute for U:
    free_save = sum(iv.save for iv in _free_intervals(trace, costs, B))
    rounded_save = round_fractional(trace.ids, trace.sizes, B, x, paid)
    upper = total - (rounded_save + free_save)
    for p in policies:
        upper = min(upper, pol.simulate(p, trace, costs, B).dollars)
    upper = max(upper, lower)  # numerical guard
    bracket = (upper - lower) / max(lower, 1e-12)
    return CostFooResult(lower, upper, total, bracket)


def _free_intervals(trace: Trace, costs: np.ndarray, B: float) -> list[Interval]:
    from .opt_exact import build_intervals
    ivs = build_intervals(trace.ids, costs, trace.sizes)
    return [iv for iv in ivs if iv.u == iv.t + 1 and iv.size <= B]
