"""cost-FOO: flow/LP bracket on the dollar-optimum for variable sizes (paper §2).

General caching with variable sizes is NP-hard (Folwarczny & Sgall 2015).
The LP relaxation of the interval program (eq. 2) is a *fractional-caching
lower bound* on billed dollars — the dollar analogue of FOO (Berger et al.
2018). A feasible schedule upper-brackets the optimum. The pair is cost-FOO;
the paper reports a median bracket (U-L)/L of ~0.04 on synthetic traces.

  L = epoch-decomposed LP (fractional, via sparse HiGHS LPs)
  U = min( greedy rounding of the LP x ,  best feasible policy in dollars )

Scaling to CDN-length traces (DESIGN.md §4):

  * `round_fractional` runs on a lazy range-add/range-min segment tree over
    the *headroom* profile zcap - occ — feasibility of an interval is one
    O(log T) range-min instead of an O(L) occupancy slice, and committing
    it is one O(log T) range-add. The pre-PR quadratic path is kept as
    `round_fractional_reference`, the oracle the tree is asserted
    bit-identical against (tests/test_cost_foo_property.py).
  * The LP lower bound is epoch-decomposed à la PFOO (Berger et al.):
    overlapping epochs are solved concurrently (HiGHS releases the GIL);
    every interval is assigned to the last epoch that starts at or before
    it, intervals too long for any epoch are credited their savings for
    free in L (a relaxation — L stays a valid lower bound) and handed to
    the global rounding with x = 1 (they must still prove feasibility
    against the full-trace occupancy, so U stays a valid upper bound).
  * The rounded schedule can be re-validated end to end through the blocked
    Pallas range-add/running-max feasibility kernel
    (`kernels.occupancy_feasible`) behind the `use_pallas`/`on_tpu()`
    dispatch — `cost_foo(..., validate=True)`.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import time

import numpy as np

from . import policies as pol
from .opt_exact import (Interval, build_interval_arrays, interval_deltas,
                        lp_solve_arrays, zcap_profile)
from .trace import Trace

__all__ = ["CostFooResult", "cost_foo", "round_fractional",
           "round_fractional_reference"]

# epoch decomposition defaults: traces at or below the threshold are solved
# monolithically (one epoch == the pre-PR LP, bit-for-bit); above it, the
# LP is split into overlapping epochs solved concurrently
_INF = float("inf")

_EPOCH_AUTO_THRESHOLD = 25_000
_EPOCH_LEN_DEFAULT = 20_000


@dataclasses.dataclass
class CostFooResult:
    lower: float            # LP fractional lower bound on billed dollars
    upper: float            # best feasible schedule, billed dollars
    total_no_cache: float
    bracket: float          # (U - L) / L
    profile: dict = dataclasses.field(default_factory=dict)  # solver counters

    @property
    def is_tight(self) -> bool:
        return self.bracket <= 0.05


def _round_tol(B: float) -> float:
    """Feasibility slack of the rounding pass, relative to the byte budget.

    An absolute 1e-9 is spuriously strict at GB budgets (where one float
    ulp of the occupancy sum already exceeds it) and meaninglessly loose
    at unit budgets; 1e-9·B tracks the precision the occupancy arithmetic
    actually has.
    """
    return 1e-9 * max(1.0, float(B))


def _occupancy_feasible(extra: Interval, occ: np.ndarray, zcap: np.ndarray,
                        tol: float) -> bool:
    """Would adding `extra` keep occupancy within B - s_{o(tau)} everywhere?"""
    a, b = extra.t + 1, extra.u - 1
    if a > b:
        return True
    seg = occ[a:b + 1] + extra.size
    return bool((seg <= zcap[a:b + 1] + tol).all())


def round_fractional_reference(ids: np.ndarray, sizes: np.ndarray, B: float,
                               x: np.ndarray, paid: list[Interval]) -> float:
    """Quadratic rounding oracle: per-interval O(L) occupancy slices.

    The pre-segment-tree implementation, kept as the ground truth that
    `round_fractional` is asserted bit-identical against and as the
    baseline of the >=5x speedup gate in benchmarks/bench_costfoo.py.
    """
    T = len(ids)
    tol = _round_tol(B)
    zcap = np.zeros(T)
    for tau in range(1, T):
        s = sizes[ids[tau]]
        zcap[tau] = B - s if s <= B else B
    occ = np.zeros(T)
    order = sorted(range(len(paid)),
                   key=lambda j: (-float(x[j] > 0.999),
                                  -float(x[j]) * paid[j].save / max(paid[j].size, 1.0)))
    saved = 0.0
    for j in order:
        iv = paid[j]
        if x[j] <= 1e-9:
            continue
        if _occupancy_feasible(iv, occ, zcap, tol):
            occ[iv.t + 1:iv.u] += iv.size
            saved += iv.save
    return saved


class _HeadroomTree:
    """Lazy range-add / range-min segment tree over the headroom profile.

    Leaves are serving instants 1..T-1 holding zcap - occ; feasibility of
    an interval is one range-min >= size - tol and committing it is one
    range-add of -size — O(log T) each vs the O(L) slice of the reference
    path. Representation: mn[v] is the min of v's subtree EXCLUDING pending
    adds at strict ancestors; add[v] is the add pending on all of v's
    subtree; so the true min of v's subtree is mn[v] + sum of add[] over
    v's strict ancestors. Plain Python lists beat numpy here — every op
    touches O(log T) scalars.
    """

    __slots__ = ("size", "mn", "add")

    def __init__(self, headroom: np.ndarray):
        n = max(1, len(headroom))
        size = 1
        while size < n:
            size <<= 1
        self.size = size
        mn = [float("inf")] * (2 * size)
        mn[size:size + len(headroom)] = [float(v) for v in headroom]
        for i in range(size - 1, 0, -1):
            mn[i] = mn[2 * i] if mn[2 * i] < mn[2 * i + 1] else mn[2 * i + 1]
        self.mn = mn
        self.add = [0.0] * (2 * size)

    def range_min(self, l: int, r: int, stop: float = -_INF) -> float:
        """Min headroom over leaves [l, r], inclusive.

        `stop` is an early-exit threshold: every pending add is <= 0 (the
        tree only ever commits -size), so a partially accumulated border
        value only DECREASES as the walk ascends — the moment it dips
        below `stop` the true range min is certainly below `stop` too, and
        that partial value (an upper bound still < stop) is returned. The
        exact min is returned whenever it is >= stop, so feasibility
        decisions `range_min(l, r, thr) >= thr` are identical to the
        exact-min ones.
        """
        mn, add = self.mn, self.add
        l += self.size
        r += self.size
        if l == r:
            res = mn[l]
            l >>= 1
            while l:
                res += add[l]
                l >>= 1
            return res
        resl, resr = mn[l], mn[r]
        lp = l >> 1
        rp = r >> 1
        while lp != rp:
            if not l & 1:
                v = mn[l + 1]
                if v < resl:
                    resl = v
            if r & 1:
                v = mn[r - 1]
                if v < resr:
                    resr = v
            resl += add[lp]
            resr += add[rp]
            v = resl if resl < resr else resr
            if v < stop:
                return v
            l = lp
            r = rp
            lp >>= 1
            rp >>= 1
        res = resl if resl < resr else resr
        while lp:
            res += add[lp]
            if res < stop:
                return res
            lp >>= 1
        return res

    def find_below(self, l: int, r: int, thr: float):
        """Locate a witness: any leaf in [l, r] with true value < thr.

        Returns (leaf, value) — value is the leaf's exact current
        headroom — or (-1, inf) when every leaf in range is >= thr.
        Guided descent: a subtree whose true min (mn[v] + strict-ancestor
        adds) is >= thr cannot contain a witness and is pruned.
        """
        mn, add = self.mn, self.add
        size = self.size
        stack = [(1, 0, size - 1, 0.0)]
        while stack:
            v, lo, hi, acc = stack.pop()
            if hi < l or lo > r or mn[v] + acc >= thr:
                continue
            if lo == hi:
                return lo, mn[v] + acc
            mid = (lo + hi) >> 1
            acc += add[v]
            stack.append((2 * v + 1, mid + 1, hi, acc))
            stack.append((2 * v, lo, mid, acc))
        return -1, _INF

    def range_add(self, l: int, r: int, v: float) -> None:
        """Add v to every leaf in [l, r], inclusive."""
        mn, add = self.mn, self.add
        l += self.size
        r += self.size
        mn[l] += v
        add[l] += v
        if l != r:
            mn[r] += v
            add[r] += v
            lp = l >> 1
            rp = r >> 1
            while lp != rp:
                if not l & 1:
                    mn[l + 1] += v
                    add[l + 1] += v
                if r & 1:
                    mn[r - 1] += v
                    add[r - 1] += v
                c = lp + lp
                a = mn[c]
                b = mn[c + 1]
                mn[lp] = (a if a < b else b) + add[lp]
                c = rp + rp
                a = mn[c]
                b = mn[c + 1]
                mn[rp] = (a if a < b else b) + add[rp]
                l = lp
                r = rp
                lp >>= 1
                rp >>= 1
            l = lp
        else:
            l >>= 1
        while l:
            c = l + l
            a = mn[c]
            b = mn[c + 1]
            mn[l] = (a if a < b else b) + add[l]
            l >>= 1


def _round_arrays(pt: np.ndarray, pu: np.ndarray, psave: np.ndarray,
                  psize: np.ndarray, x: np.ndarray, zcap: np.ndarray,
                  tol: float):
    """Segment-tree rounding over flat interval arrays.

    Same greedy as the reference — identical ordering keys (evaluated with
    the exact same float expression shapes) and identical feasibility
    predicate, re-expressed as headroom range-mins — so accepted sets and
    the saved-dollar sum match the oracle bit for bit when the occupancy
    arithmetic is exact (integer-valued sizes). Returns (saved, accepted
    interval indices).
    """
    m = len(pt)
    if m == 0:
        return 0.0, []
    # reference key: (-(x > 0.999), -x * save / max(size, 1)); lexsort is
    # stable ascending with the LAST key primary, matching sorted()
    dens = (-x) * psave / np.maximum(psize, 1.0)
    pref = -(x > 0.999).astype(np.float64)
    order = np.lexsort((dens, pref))
    tree = _HeadroomTree(zcap[1:])   # leaf k = instant k+1
    mn = tree.mn
    range_min = tree.range_min
    range_add = tree.range_add
    find_below = tree.find_below
    l_arr = pt.tolist()              # covers instants t+1..u-1 = leaves t..u-2
    r_arr = (pu - 2).tolist()
    sv = psave.tolist()
    sz = psize.tolist()
    xv = x.tolist()
    saved = 0.0
    accepted: list[int] = []
    # bottleneck cache: a known instant and its EXACT current headroom
    # (kept exact by debiting covering accepts). Adds only ever decrease
    # headroom, so "bad_tau in range and bad_h < s - tol" proves the range
    # min is < s - tol without walking the tree — O(1) rejects once the
    # profile saturates (the common case on scan-like traffic). Witness
    # probes cost a walk themselves, so they back off exponentially on
    # workloads where cached bottlenecks never land inside later ranges
    bad_tau = -1
    bad_h = _INF
    probe_gap = 1                    # walk-rejects until the next probe
    since_probe = 0
    cache_hit = False
    for j in order.tolist():
        if xv[j] <= 1e-9:
            continue
        l = l_arr[j]
        r = r_arr[j]
        s = sz[j]
        if l > r:                    # no interior instant: free to keep
            saved += sv[j]
            accepted.append(j)
            continue
        thr = s - tol
        if l <= bad_tau <= r and bad_h < thr:
            cache_hit = True
            continue                 # bottleneck proves infeasibility
        # mn[1] is the global min headroom (the root has no ancestors):
        # while the cache is loosely packed the range query short-circuits;
        # once packed, the threshold lets the walk abort mid-climb
        if mn[1] >= thr or range_min(l, r, thr) >= thr:
            range_add(l, r, -s)
            saved += sv[j]
            accepted.append(j)
            if l <= bad_tau <= r:
                bad_h -= s
        else:
            since_probe += 1
            if since_probe >= probe_gap:
                bad_tau, bad_h = find_below(l, r, thr)
                probe_gap = 1 if cache_hit else min(probe_gap * 2, 256)
                cache_hit = False
                since_probe = 0
    return saved, accepted


def round_fractional(ids: np.ndarray, sizes: np.ndarray, B: float,
                     x: np.ndarray, paid: list[Interval],
                     return_accepted: bool = False):
    """PFOO-like rounding: greedily retain gaps by LP preference (x, then
    dollar density), keeping the occupancy profile feasible. Returns the
    dollars *saved* by the resulting feasible schedule (and the accepted
    interval indices when `return_accepted`).

    O((T + m) log T) on the headroom segment tree; see
    `round_fractional_reference` for the O(T·L) oracle it replays exactly.
    """
    ids = np.asarray(ids)
    m = len(paid)
    pt = np.fromiter((iv.t for iv in paid), np.int64, m)
    pu = np.fromiter((iv.u for iv in paid), np.int64, m)
    ps = np.fromiter((iv.save for iv in paid), np.float64, m)
    pz = np.fromiter((iv.size for iv in paid), np.float64, m)
    zcap = zcap_profile(ids, sizes, B)
    saved, accepted = _round_arrays(pt, pu, ps, pz, np.asarray(x, np.float64),
                                    zcap, _round_tol(B))
    return (saved, accepted) if return_accepted else saved


def _epoch_plan(T: int, epoch_len: int, overlap: float):
    """(stride, epoch count) for the overlapping-epoch decomposition."""
    epoch_len = max(2, min(int(epoch_len), T))
    if epoch_len >= T:
        return T, 1, epoch_len
    stride = max(1, int(round(epoch_len * (1.0 - overlap))))
    return stride, (T - 1) // stride + 1, epoch_len


def cost_foo(trace: Trace, costs: np.ndarray, B: float,
             policies: tuple[str, ...] = ("gdsf", "gds", "cost_belady", "belady"),
             epoch_len: int | None = None, epoch_overlap: float = 0.5,
             max_workers: int | None = None, validate: bool = False,
             use_pallas: bool | None = None) -> CostFooResult:
    """Bracket OPT-dollars on a variable-size trace (DESIGN.md §4).

    `epoch_len=None` solves monolithically up to T=25k and decomposes into
    overlapping 20k epochs beyond that; pass an explicit `epoch_len` to
    force either. `validate=True` replays the rounded schedule through the
    Pallas occupancy-feasibility kernel (device-resident on TPU,
    interpreted elsewhere) and asserts it never exceeds zcap.
    """
    t_start = time.perf_counter()
    ids = np.asarray(trace.ids)
    sizes = np.asarray(trace.sizes, np.float64)
    costs = np.asarray(costs, np.float64)
    T = len(ids)
    B = float(B)
    total = float(costs[ids].sum()) if T else 0.0
    t_arr, u_arr, obj, save, size = build_interval_arrays(ids, costs, sizes)
    fits = size <= B
    free_save = float(save[fits & (u_arr == t_arr + 1)].sum())
    paidm = fits & (u_arr > t_arr + 1)
    pt, pu = t_arr[paidm], u_arr[paidm]
    ps, pz = save[paidm], size[paidm]
    m = len(pt)
    if epoch_len is None:
        epoch_len = T if T <= _EPOCH_AUTO_THRESHOLD else _EPOCH_LEN_DEFAULT
    profile: dict = dict(requests=int(T), paid_intervals=int(m))
    if m == 0 or T <= 1:
        lower = upper = total - free_save
        for p in policies:
            upper = min(upper, pol.simulate(p, trace, costs, B).dollars)
        upper = max(upper, lower)
        bracket = (upper - lower) / max(lower, 1e-12)
        return CostFooResult(lower, upper, total, bracket, profile)

    zcap = zcap_profile(ids, sizes, B)
    stride, n_epochs, epoch_len = _epoch_plan(T, epoch_len, epoch_overlap)
    profile.update(epochs=int(n_epochs), epoch_len=int(epoch_len),
                   stride=int(stride))

    # stitching rule (DESIGN.md §4): each interval goes to the LAST epoch
    # starting at or before its t (maximal right headroom); intervals whose
    # gap outlives the epoch overlap are "crossing" — free savings credit
    # in L (relaxation), x = 1/2 into the global rounding for U: positive,
    # so they can fill leftover headroom by dollar density, but OUTSIDE the
    # preferred x≈1 class — no epoch LP accounted for their load, and at
    # x = 1 they crowd out the LPs' chosen intervals during rounding
    k_j = np.minimum(pt // stride, n_epochs - 1)
    e_per = np.minimum(k_j * stride + epoch_len, T)
    contained = pu < e_per
    crossing_save = float(ps[~contained].sum())
    profile["crossing_intervals"] = int((~contained).sum())

    t_lp = time.perf_counter()
    x = np.zeros(m)
    x[~contained] = 0.5
    jobs = []
    for k in range(n_epochs):
        a = k * stride
        e = min(a + epoch_len, T)
        sel = np.flatnonzero(contained & (k_j == k))
        if len(sel) and e - a > 1:
            jobs.append((a, e, sel))

    def _solve(job):
        a, e, sel = job
        return sel, lp_solve_arrays(pt[sel] - a, pu[sel] - a, ps[sel],
                                    pz[sel], zcap[a + 1:e], e - a - 1)

    if len(jobs) <= 1 or (max_workers is not None and max_workers <= 1):
        results = [_solve(j) for j in jobs]
    else:
        workers = min(len(jobs), max_workers or (os.cpu_count() or 1))
        with concurrent.futures.ThreadPoolExecutor(workers) as ex:
            results = list(ex.map(_solve, jobs))
    lp_savings = 0.0
    for sel, (sav, xk) in results:
        lp_savings += sav
        x[sel] = xk
    lower = total - (lp_savings + crossing_save + free_save)
    profile["lp_seconds"] = time.perf_counter() - t_lp

    t_round = time.perf_counter()
    rounded_save, accepted = _round_arrays(pt, pu, ps, pz, x, zcap,
                                           _round_tol(B))
    profile["round_seconds"] = time.perf_counter() - t_round
    profile["rounded_intervals"] = len(accepted)
    if validate and accepted:
        _validate_schedule(pt, pu, pz, accepted, zcap, T, B, use_pallas)

    upper = total - (rounded_save + free_save)
    for p in policies:
        upper = min(upper, pol.simulate(p, trace, costs, B).dollars)
    upper = max(upper, lower)  # numerical guard
    bracket = (upper - lower) / max(lower, 1e-12)
    profile["total_seconds"] = time.perf_counter() - t_start
    return CostFooResult(lower, upper, total, bracket, profile)


def _validate_schedule(pt, pu, pz, accepted, zcap, T, B, use_pallas):
    """Replay the accepted schedule through the occupancy kernel.

    The kernel scans in float32, so the tolerance is the float32 precision
    of a B-sized running sum, not the rounding pass's own 1e-9·B.
    """
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    acc = np.asarray(accepted, np.int64)
    deltas = interval_deltas(pt[acc], pu[acc], pz[acc], T)
    _, excess = kops.occupancy_feasible(jnp.asarray(deltas, jnp.float32),
                                        jnp.asarray(zcap, jnp.float32),
                                        use_pallas=use_pallas)
    tol = max(_round_tol(B), 1e-4 * max(1.0, B))
    if float(excess) > tol:
        raise AssertionError(
            f"rounded schedule exceeds zcap by {float(excess):.6g} "
            f"(tolerance {tol:.6g})")


def _free_intervals(trace: Trace, costs: np.ndarray, B: float) -> list[Interval]:
    from .opt_exact import build_intervals
    ivs = build_intervals(trace.ids, costs, trace.sizes)
    return [iv for iv in ivs if iv.u == iv.t + 1 and iv.size <= B]
