# Model zoo: ArchConfig-driven dense / MoE / recurrent / enc-dec families
# behind one ModelApi (prefill + decode_step is all serve needs).
from .common import (ArchConfig, ParamDef, abstract_params, axes_tree,
                     init_params)
from .registry import ModelApi, get_model

__all__ = ["ArchConfig", "ParamDef", "init_params", "abstract_params",
           "axes_tree", "ModelApi", "get_model"]
