"""Mixture-of-Experts FFN: shared experts + routed top-k, sort-based dispatch.

Covers kimi-k2 (384 routed / top-8 / 1 shared, first layer dense) and
qwen2-moe (60 routed / top-4 / 4 shared).

Dispatch is the TPU-friendly sort-within-group form (DESIGN.md §5):
tokens are routed *within their leading group* (a sequence for training,
a data-parallel shard group for decode), so the argsort and the capacity
buffer never cross the data-parallel sharding — zero all-to-all in the
baseline. Expert weights (E, d, f) are FSDP+TP sharded on (d, f); an
expert-parallel variant (E over the model axis, all-to-all dispatch) is a
config flag evaluated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import DP_AXES, ArchConfig, ParamDef, constrain

__all__ = ["moe_ffn_defs", "moe_ffn_apply"]


def moe_ffn_defs(cfg: ArchConfig) -> dict:
    d, E, fe = cfg.d_model, cfg.num_experts, cfg.d_expert or cfg.d_ff
    out = {
        "router": ParamDef((d, E), ("embed", None), dtype=jnp.float32),
        "w1": ParamDef((E, d, fe), ("expert", "embed", "mlp")),
        "w3": ParamDef((E, d, fe), ("expert", "embed", "mlp")),
        "w2": ParamDef((E, fe, d), ("expert", "mlp", "embed")),
    }
    if cfg.num_shared_experts:
        fs = fe * cfg.num_shared_experts
        out["shared"] = {
            "w1": ParamDef((d, fs), ("embed", "mlp")),
            "w3": ParamDef((d, fs), ("embed", "mlp")),
            "w2": ParamDef((fs, d), ("mlp", "embed")),
        }
    return out


def _dispatch_batched(cfg: ArchConfig, p, x):
    """Route every row's tokens within the row. x: (B, S, d) -> (B, S, d).

    Fully batched (no vmap) so every intermediate keeps the explicit B
    leading dim and can be constrained to stay on the data-parallel shard —
    without the constraints GSPMD replicates the gather/scatter operands
    across the TP axis (measured: 42 GiB -> ~6 GiB/device on qwen2-moe).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    TK = S * K
    C = max(1, int(S * K * cfg.capacity_factor / E))

    logits = (x.astype(jnp.float32) @ p["router"])            # (B, S, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gw, gi = jax.lax.top_k(gates, K)                          # (B, S, K)
    gw = (gw / jnp.maximum(gw.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    flat_e = gi.reshape(B, TK)                                # (B, TK)
    order = jnp.argsort(flat_e, axis=-1)                      # stable, per row
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_of = order // K                                       # (B, TK)
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, TK))
    counts = jnp.zeros((B, E), jnp.int32).at[rows, sorted_e].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos = (jnp.arange(TK, dtype=jnp.int32)[None]
           - jnp.take_along_axis(starts, sorted_e, axis=-1))
    keep = pos < C
    slot = jnp.where(keep, pos, 0)

    vals = jnp.take_along_axis(x, tok_of[..., None], axis=1)  # (B, TK, d)
    vals = jnp.where(keep[..., None], vals, 0)
    vals = constrain(vals, DP_AXES, None, None)
    buf = jnp.zeros((B, E, C, d), x.dtype).at[rows, sorted_e, slot].add(vals)
    buf = constrain(buf, DP_AXES, None, None, None)
    wflat = jnp.take_along_axis(gw.reshape(B, TK), order, axis=-1)

    mesh = _act_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        # §Perf iteration (EXPERIMENTS.md, MoE cells): sink the TP psum of
        # the w2 contraction through the (linear) slot->token combine, so
        # the all-reduce moves over (B,S,d) tokens instead of the ~K*cf x
        # larger (B,E,C,d) slot buffer. GSPMD can't sink reductions through
        # scatter/gather; shard_map states it explicitly.
        y = _ffn_combine_shardmap(cfg, p, mesh, buf, sorted_e, slot, keep,
                                  wflat, tok_of, S)
    else:
        h1 = jnp.einsum("becd,edf->becf", buf, p["w1"])
        h3 = jnp.einsum("becd,edf->becf", buf, p["w3"])
        h = jax.nn.silu(h1) * h3
        out_e = jnp.einsum("becf,efd->becd", h, p["w2"])      # (B, E, C, d)
        gathered = out_e[rows, sorted_e, slot]                # (B, TK, d)
        gathered = jnp.where(keep[..., None], gathered, 0)
        y = jnp.zeros((B, S, d), x.dtype).at[
            rows, tok_of].add(gathered * wflat[..., None])
    return constrain(y, DP_AXES, None, None)


def _act_mesh():
    from . import common
    return common._ACT_MESH


def _ffn_combine_shardmap(cfg, p, mesh, buf, sorted_e, slot, keep, wflat,
                          tok_of, S):
    """Expert FFN + slot->token combine with the TP psum on token space."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, E, C, d = buf.shape
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if B % max(dp_size, 1) != 0 or dp_size == 1:
        dp_spec = None

    def local(buf_l, w1_l, w3_l, w2_l, se_l, slot_l, keep_l, wf_l, tok_l):
        Bl = buf_l.shape[0]
        rows_l = jnp.broadcast_to(jnp.arange(Bl)[:, None], se_l.shape)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf_l, w1_l)) \
            * jnp.einsum("becd,edf->becf", buf_l, w3_l)
        out_e = jnp.einsum("becf,efd->becd", h, w2_l)   # partial over model
        g = out_e[rows_l, se_l, slot_l]
        g = jnp.where(keep_l[..., None], g, 0) * wf_l[..., None]
        y_part = jnp.zeros((Bl, S, d), buf_l.dtype).at[rows_l, tok_l].add(g)
        return jax.lax.psum(y_part, "model")

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp_spec, None, None, None),      # buf: rows on DP
                  P(None, None, "model"),            # w1 (FSDP gather first)
                  P(None, None, "model"),            # w3
                  P(None, "model", None),            # w2
                  P(dp_spec, None), P(dp_spec, None),
                  P(dp_spec, None), P(dp_spec, None), P(dp_spec, None)),
        out_specs=P(dp_spec, None, None),
        check_rep=False)
    return fn(buf, p["w1"], p["w3"], p["w2"], sorted_e, slot, keep,
              wflat.astype(buf.dtype), tok_of)


def _decode_gather(cfg: ArchConfig, p, x):
    """One-token decode path: gather the top-k experts' weights per token
    instead of dispatching tokens to experts — FLOP-minimal (B*k*d*f) and
    bytes-dominated, which is the true MoE decode regime. x: (B, 1, d)."""
    B, _, d = x.shape
    K = cfg.top_k
    x0 = x[:, 0]
    logits = x0.astype(jnp.float32) @ p["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    gw, gi = jax.lax.top_k(gates, K)                          # (B, K)
    gw = (gw / jnp.maximum(gw.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)
    w1g = constrain(p["w1"][gi], DP_AXES, None, None, "model")  # (B,K,d,f)
    w3g = constrain(p["w3"][gi], DP_AXES, None, None, "model")
    w2g = constrain(p["w2"][gi], DP_AXES, None, "model", None)  # (B,K,f,d)
    h = jnp.einsum("bd,bkdf->bkf", x0, w1g)
    h = jax.nn.silu(h) * jnp.einsum("bd,bkdf->bkf", x0, w3g)
    y = jnp.einsum("bkf,bkfd->bd", h * gw[..., None], w2g)
    return constrain(y, DP_AXES, None)[:, None]


def moe_ffn_apply(cfg: ArchConfig, p, x):
    """x: (B, S, d). Routing groups = rows of the leading batch dim (stay
    DP-sharded); S == 1 takes the decode weight-gather path."""
    B, S, d = x.shape
    if S == 1:
        y = _decode_gather(cfg, p, x)
    else:
        y = _dispatch_batched(cfg, p, x)
    if "shared" in p:
        sh = p["shared"]
        y = y + (jax.nn.silu(x @ sh["w1"]) * (x @ sh["w3"])) @ sh["w2"]
    return y