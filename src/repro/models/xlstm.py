"""xLSTM (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

xlstm-125m: 12 layers, d=768, 4 heads, no separate FFN (d_ff=0) — the
blocks carry their own gated up/down projections.

TPU adaptation (DESIGN.md §3):
  * mLSTM training uses the paper's *parallel form* — a decay-masked
    attention built from cumulative log-forget-gates (quadratic in S, like
    the paper's own training mode) — and the O(1)-state *recurrent form*
    (C, n, m) for decode, which is what makes the long_500k cell runnable.
  * sLSTM is a stabilized elementwise linear recurrence, trained with
    jax.lax.associative_scan (Blelloch), decoded step-recurrently.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import (DP_AXES, ArchConfig, ParamDef, constrain, rms_norm,
                     softmax_xent)

__all__ = ["param_defs", "loss_fn", "prefill", "decode_step", "forward"]


def _mlstm_defs(cfg: ArchConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wq": ParamDef((d, d), ("embed", "heads")),
        "wk": ParamDef((d, d), ("embed", "heads")),
        "wv": ParamDef((d, d), ("embed", "heads")),
        "wi": ParamDef((d, H), ("embed", None)),   # input gate (per head)
        "wf": ParamDef((d, H), ("embed", None)),   # forget gate (per head)
        "wo": ParamDef((d, d), ("heads", "embed")),
        "wog": ParamDef((d, d), ("embed", "heads")),  # output gate proj
    }


def _slstm_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wz": ParamDef((d, d), ("embed", "mlp")),
        "wi": ParamDef((d, d), ("embed", "mlp")),
        "wf": ParamDef((d, d), ("embed", "mlp")),
        "wo": ParamDef((d, d), ("embed", "mlp")),
        "wdown": ParamDef((d, d), ("mlp", "embed")),
    }


def param_defs(cfg: ArchConfig) -> dict:
    layers = []
    for l in range(cfg.num_layers):
        if l % 2 == 0:
            layers.append({"kind_mlstm": _mlstm_defs(cfg)})
        else:
            layers.append({"kind_slstm": _slstm_defs(cfg)})
    return {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          scale=1.0),
        "layers": layers,
        "ln_f": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


# --------------------------- mLSTM ----------------------------------------

_MLSTM_CHUNK = 256


def _mlstm_parallel(cfg: ArchConfig, p, x):
    """Chunkwise-parallel training form (xLSTM paper's training mode):
    intra-chunk decay-masked attention + inter-chunk recurrent (C, n, m)
    state carried by lax.scan. Linear in S with quadratic chunks.
    x: (B, S, d)."""
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    L = min(_MLSTM_CHUNK, S)
    pad = (-S) % L
    xn = rms_norm(x, p["ln"])
    q = (xn @ p["wq"]).reshape(B, S, H, hd)
    k = (xn @ p["wk"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = (xn @ p["wv"]).reshape(B, S, H, hd)
    logf = jax.nn.log_sigmoid((xn @ p["wf"]).astype(jnp.float32))  # (B,S,H)
    logi = (xn @ p["wi"]).astype(jnp.float32)
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
    NC = (S + pad) // L

    def to_chunks(t):
        return t.reshape((B, NC, L) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = map(to_chunks, (q, k, v))      # (NC, B, L, H, hd)
    fc, ic = map(to_chunks, (logf, logi))       # (NC, B, L, H)

    def chunk_step(carry, inp):
        C, n, m = carry                         # (B,H,hd,hd), (B,H,hd), (B,H)
        qb, kb, vb, fb, ib = inp
        b = jnp.cumsum(fb, axis=1)              # (B, L, H) inclusive
        F = b[:, -1]                            # (B, H) chunk decay total
        # stabilizers
        runmax = jax.lax.cummax(ib - b, axis=1)         # (B, L, H)
        m_i = jnp.maximum(b + m[:, None], b + runmax)   # (B, L, H)
        # intra-chunk: D_ij = b_i - b_j + i_j - m_i  (j <= i)
        Dm = (b[:, :, None] - b[:, None, :] + ib[:, None, :]
              - m_i[:, :, None])                        # (B, L, L, H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        Dexp = jnp.where(causal[None, :, :, None], jnp.exp(Dm), 0.0)
        scores = jnp.einsum("blhd,bshd->blsh", qb, kb).astype(jnp.float32) * Dexp
        inter_scale = jnp.exp(b + m[:, None] - m_i)     # (B, L, H)
        num = jnp.einsum("blsh,bshd->blhd", scores, vb.astype(jnp.float32))
        num += inter_scale[..., None] * jnp.einsum(
            "blhd,bhdv->blhv", qb.astype(jnp.float32), C)
        # n_i = sum_j Dexp_ij k_j + inter_scale * n_prev
        n_i = jnp.einsum("blsh,bshd->blhd", Dexp, kb.astype(jnp.float32)) \
            + inter_scale[..., None] * n[:, None]
        den = jnp.maximum(jnp.abs(jnp.einsum("blhd,blhd->blh",
                                             qb.astype(jnp.float32), n_i)),
                          jnp.exp(-m_i))
        h = (num / den[..., None])
        # state update to end of chunk
        m_new = F + jnp.maximum(m, jnp.max(ib - b, axis=1))
        w_j = jnp.exp(F[:, None] - b + ib - m_new[:, None])   # (B, L, H)
        C_new = jnp.exp(F + m - m_new)[..., None, None] * C + jnp.einsum(
            "blh,blhd,blhv->bhdv", w_j, kb.astype(jnp.float32),
            vb.astype(jnp.float32))
        n_new = jnp.exp(F + m - m_new)[..., None] * n + jnp.einsum(
            "blh,blhd->bhd", w_j, kb.astype(jnp.float32))
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, fc, ic))
    h = hs.swapaxes(0, 1).reshape(B, S + pad, H, hd)[:, :S]
    h = h.astype(x.dtype).reshape(B, S, d)
    og = jax.nn.sigmoid(xn @ p["wog"])
    return (h * og) @ p["wo"]


def _mlstm_decode(cfg: ArchConfig, p, x, state):
    """Recurrent form. x: (B, 1, d); state = (C, n, m) with
    C: (B, H, hd, hd), n: (B, H, hd), m: (B, H)."""
    B, _, d = x.shape
    H = cfg.num_heads
    hd = d // H
    C, n, m = state
    xn = rms_norm(x[:, 0], p["ln"])
    q = (xn @ p["wq"]).reshape(B, H, hd)
    k = (xn @ p["wk"]).reshape(B, H, hd) / math.sqrt(hd)
    v = (xn @ p["wv"]).reshape(B, H, hd)
    logf = jax.nn.log_sigmoid((xn @ p["wf"]).astype(jnp.float32))  # (B,H)
    logi = (xn @ p["wi"]).astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    fg = jnp.exp(logf + m - m_new)[..., None]
    ig = jnp.exp(logi - m_new)[..., None]
    Cf = C.astype(jnp.float32)
    nf = n.astype(jnp.float32)
    C_new = fg[..., None] * Cf + (ig * v.astype(jnp.float32))[..., :, None] \
        * k.astype(jnp.float32)[..., None, :]
    n_new = fg * nf + ig * k.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C_new, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new,
                                         q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(x.dtype).reshape(B, d)
    og = jax.nn.sigmoid(xn @ p["wog"])
    out = ((h * og) @ p["wo"])[:, None]
    return out, (C_new.astype(C.dtype), n_new.astype(n.dtype), m_new)


# --------------------------- sLSTM ----------------------------------------

def _slstm_scan(cfg: ArchConfig, p, x):
    """Training form: stabilized elementwise linear recurrence via
    associative_scan. x: (B, S, d)."""
    xn = rms_norm(x, p["ln"])
    z = jnp.tanh(xn @ p["wz"]).astype(jnp.float32)
    logi = (xn @ p["wi"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid((xn @ p["wf"]).astype(jnp.float32))
    o = jax.nn.sigmoid(xn @ p["wo"])
    # c_t = f c_{t-1} + i z ; n_t = f n_{t-1} + i   (stabilized by m_t)
    # associative linear recurrence on (a, b): y_t = a_t y_{t-1} + b_t

    def combine(l, r):
        al, bl, nl = l
        ar, br, nr = r
        return al * ar, ar * bl + br, ar * nl + nr

    a = jnp.exp(logf)  # safe: log_sigmoid <= 0 -> a in (0, 1]
    i = jnp.exp(jnp.minimum(logi, 10.0))
    # scan c_t and n_t together: both share the decay a_t
    _, c_t = jax.lax.associative_scan(
        lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]), (a, i * z), axis=1)[0:2]
    _, n_t = jax.lax.associative_scan(
        lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]), (a, i), axis=1)[0:2]
    h = (c_t / jnp.maximum(n_t, 1e-6)).astype(x.dtype)
    return (h * o) @ p["wdown"]


def _slstm_decode(cfg: ArchConfig, p, x, state):
    """state = (c, n): (B, d) each."""
    c, n = state
    xn = rms_norm(x[:, 0], p["ln"])
    z = jnp.tanh(xn @ p["wz"]).astype(jnp.float32)
    i = jnp.exp(jnp.minimum((xn @ p["wi"]).astype(jnp.float32), 10.0))
    f = jax.nn.sigmoid((xn @ p["wf"]).astype(jnp.float32))
    o = jax.nn.sigmoid(xn @ p["wo"])
    c_new = f * c + i * z
    n_new = f * n + i
    h = (c_new / jnp.maximum(n_new, 1e-6)).astype(x.dtype)
    return ((h * o) @ p["wdown"])[:, None], (c_new, n_new)


# --------------------------- model ----------------------------------------

def _apply_layer(cfg, p, x):
    if "kind_mlstm" in p:
        return x + _mlstm_parallel(cfg, p["kind_mlstm"], x)
    return x + _slstm_scan(cfg, p["kind_slstm"], x)


def forward(cfg: ArchConfig, params, batch, *, remat: bool = True):
    x = params["embed"][batch["tokens"]].astype(cfg.param_dtype)
    x = constrain(x, DP_AXES, None, None)
    for p in params["layers"]:
        f = jax.checkpoint(lambda p_, x_: _apply_layer(cfg, p_, x_)) \
            if remat else (lambda p_, x_: _apply_layer(cfg, p_, x_))
        x = f(p, x)
    x = rms_norm(x, params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return constrain(logits, DP_AXES, None, "model")


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits = forward(cfg, params, batch, remat=remat)
    return softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab_size)


def init_state(cfg: ArchConfig, B: int, dtype):
    """Per-layer recurrent decode state."""
    H = cfg.num_heads
    hd = cfg.d_model // H
    states = []
    for l in range(cfg.num_layers):
        if l % 2 == 0:
            states.append((jnp.zeros((B, H, hd, hd), dtype),
                           jnp.zeros((B, H, hd), dtype),
                           jnp.full((B, H), -1e30, jnp.float32)))
        else:
            states.append((jnp.zeros((B, cfg.d_model), jnp.float32),
                           jnp.zeros((B, cfg.d_model), jnp.float32)))
    return states


def prefill(cfg: ArchConfig, params, batch):
    """Stateless stress prefill: forward for logits + fresh decode state.

    (The recurrent state could be produced by a scan over the prompt; for
    the dry-run cells the forward pass dominates and state init is O(1).)"""
    logits = forward(cfg, params, batch, remat=False)
    B = batch["tokens"].shape[0]
    return logits[:, -1], init_state(cfg, B, cfg.param_dtype)


def decode_step(cfg: ArchConfig, params, token, states, position):
    B = token.shape[0]
    x = params["embed"][token][:, None].astype(cfg.param_dtype)
    new_states = []
    for p, st in zip(params["layers"], states):
        if "kind_mlstm" in p:
            h, st2 = _mlstm_decode(cfg, p["kind_mlstm"], x, st)
        else:
            h, st2 = _slstm_decode(cfg, p["kind_slstm"], x, st)
        x = x + h
        new_states.append(st2)
    x = rms_norm(x, params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return logits[:, 0], new_states