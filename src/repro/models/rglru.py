"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention, 1:2.

Layer pattern repeats (recurrent, recurrent, local-attn). The recurrent
block is: input proj -> short temporal conv -> RG-LRU gated linear
recurrence -> gated output proj. RG-LRU:

    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)           (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses jax.lax.associative_scan (linear recurrence); decode keeps an
O(1) state per layer — this is what makes long_500k runnable (DESIGN.md §6).
Local attention layers use a sliding window (2048) with the shared GQA code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (DP_AXES, ArchConfig, ParamDef, apply_rope, attention,
                     chunked_attention, constrain, ffn, rms_norm,
                     softmax_xent)

__all__ = ["param_defs", "loss_fn", "prefill", "decode_step", "forward"]

_C = 8.0
_FULL_ATTN_LIMIT = 2048 * 2048


def _rec_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru_conv_width
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wx": ParamDef((d, d), ("embed", "mlp")),
        "wy": ParamDef((d, d), ("embed", "mlp")),     # gate branch
        "conv": ParamDef((w, d), (None, "mlp")),
        "wr": ParamDef((d, d), ("embed", "mlp")),
        "wi": ParamDef((d, d), ("embed", "mlp")),
        "lam": ParamDef((d,), ("mlp",), init="normal", scale=0.5),
        "wout": ParamDef((d, d), ("mlp", "embed")),
    }


def _attn_defs(cfg: ArchConfig) -> dict:
    d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wq": ParamDef((d, H * hd), ("embed", "heads")),
        "wk": ParamDef((d, G * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, G * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, d), ("heads", "embed")),
    }


def _ffn_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "w1": ParamDef((d, cfg.d_ff), ("embed", "mlp")),
        "w3": ParamDef((d, cfg.d_ff), ("embed", "mlp")),
        "w2": ParamDef((cfg.d_ff, d), ("mlp", "embed")),
    }


def param_defs(cfg: ArchConfig) -> dict:
    layers = []
    for l in range(cfg.num_layers):
        blk = {"ffn": _ffn_defs(cfg)}
        if cfg.is_attn_layer(l):
            blk["attn"] = _attn_defs(cfg)
        else:
            blk["rec"] = _rec_defs(cfg)
        layers.append(blk)
    return {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          scale=1.0),
        "layers": layers,
        "ln_f": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


# --------------------------- RG-LRU block ---------------------------------

def _rglru_gates(p, xn):
    r = jax.nn.sigmoid((xn @ p["wr"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xn @ p["wi"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i
    return a, gated


def _conv1d(p, x, state=None):
    """Short causal temporal conv. x: (B, S, d). state: (B, w-1, d) or None."""
    w = p["conv"].shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, k:k + x.shape[1]] * p["conv"][k] for k in range(w))
    new_state = xp[:, -(w - 1):] if w > 1 else None
    return out, new_state


def _rec_block(cfg: ArchConfig, p, x, state=None):
    """Returns (out, (h_last, conv_state))."""
    xn = rms_norm(x, p["ln"])
    u = xn @ p["wx"]
    gate = jax.nn.gelu(xn @ p["wy"])
    conv_state = state[1] if state is not None else None
    u, new_conv = _conv1d(p, u, conv_state)
    a, gated = _rglru_gates(p, xn)
    b = gated * u.astype(jnp.float32)
    if x.shape[1] == 1 and state is not None:  # decode fast path
        h_prev = state[0]
        h = a[:, 0] * h_prev + b[:, 0]
        hs = h[:, None]
        h_last = h
    else:
        if state is not None:
            # seed the scan with the carried state via a virtual step
            b = b.at[:, 0].add(a[:, 0] * state[0])
        _, hs = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]), (a, b), axis=1)[0:2]
        h_last = hs[:, -1]
    out = (hs.astype(x.dtype) * gate) @ p["wout"]
    return out, (h_last, new_conv)


def _attn_block(cfg: ArchConfig, p, x, positions, q_offset=0, kv_cache=None):
    B, S, _ = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    xn = rms_norm(x, p["ln"])
    q = (xn @ p["wq"]).reshape(B, S, H, hd)
    k = (xn @ p["wk"]).reshape(B, S, G, hd)
    v = (xn @ p["wv"]).reshape(B, S, G, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), q_offset, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), q_offset, 1)
        k, v = ck, cv
        new_cache = (ck, cv)
    else:
        new_cache = (k, v)
    fn = attention if q.shape[1] * k.shape[1] <= _FULL_ATTN_LIMIT else chunked_attention
    out = fn(q, k.astype(q.dtype), v.astype(q.dtype), causal=True,
             window=cfg.window, q_offset=q_offset)
    return out @ p["wo"], new_cache


def _attn_decode_windowed(cfg: ArchConfig, p, x, position, kv_cache):
    """One-token decode against a W-sized *shift* cache (oldest key drops
    off the front every step). Keys live at absolute positions
    position-W+1 .. position; negative positions are masked inside
    attention()."""
    B = x.shape[0]
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    xn = rms_norm(x, p["ln"])
    q = (xn @ p["wq"]).reshape(B, 1, H, hd)
    k = (xn @ p["wk"]).reshape(B, 1, G, hd)
    v = (xn @ p["wv"]).reshape(B, 1, G, hd)
    positions = jnp.broadcast_to(jnp.asarray(position, jnp.int32)[None, None],
                                 (B, 1))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    ck, cv = kv_cache
    W = ck.shape[1]
    ck = jnp.concatenate([ck[:, 1:], k.astype(ck.dtype)], axis=1)
    cv = jnp.concatenate([cv[:, 1:], v.astype(cv.dtype)], axis=1)
    k_offset = jnp.asarray(position, jnp.int32) - W + 1
    out = attention(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=True,
                    window=cfg.window, q_offset=position, k_offset=k_offset)
    return out @ p["wo"], (ck, cv)


def _layer(cfg, l, p, x, positions, q_offset=0, cache=None):
    if "attn" in p:
        h, new_cache = _attn_block(cfg, p["attn"], x, positions,
                                   q_offset=q_offset, kv_cache=cache)
    else:
        h, new_cache = _rec_block(cfg, p["rec"], x, state=cache)
    x = x + h
    f = p["ffn"]
    x = x + ffn(rms_norm(x, f["ln"]), f["w1"], f["w3"], f["w2"], "swiglu")
    return x, new_cache


def forward(cfg: ArchConfig, params, batch, *, remat: bool = True):
    x = params["embed"][batch["tokens"]].astype(cfg.param_dtype)
    x = constrain(x, DP_AXES, None, None)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    for l, p in enumerate(params["layers"]):
        if remat:
            x = jax.checkpoint(
                lambda p_, x_, _l=l: _layer(cfg, _l, p_, x_, positions)[0])(p, x)
        else:
            x, _ = _layer(cfg, l, p, x, positions)
    x = rms_norm(x, params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return constrain(logits, DP_AXES, None, "model")


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits = forward(cfg, params, batch, remat=remat)
    return softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab_size)


def init_caches(cfg: ArchConfig, B: int, max_seq: int, dtype):
    """Attention layers: windowed KV cache (capped at cfg.window — the whole
    point of local attention); recurrent layers: (h, conv) state."""
    caches = []
    G, hd, d = cfg.num_kv_heads, cfg.hd, cfg.d_model
    w = cfg.rglru_conv_width
    kv_len = min(max_seq, cfg.window) if cfg.window else max_seq
    for l in range(cfg.num_layers):
        if cfg.is_attn_layer(l):
            caches.append((jnp.zeros((B, kv_len, G, hd), dtype),
                           jnp.zeros((B, kv_len, G, hd), dtype)))
        else:
            caches.append((jnp.zeros((B, d), jnp.float32),
                           jnp.zeros((B, w - 1, d), dtype)))
    return caches


def prefill(cfg: ArchConfig, params, batch):
    x = params["embed"][batch["tokens"]].astype(cfg.param_dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    caches = []
    W = cfg.window or S
    for l, p in enumerate(params["layers"]):
        x, c = _layer(cfg, l, p, x, positions)
        if "attn" in p:
            # keep only the last W keys as a shift cache (left-pad if short;
            # padded slots sit at negative absolute positions -> masked)
            ck, cv = c
            take = min(S, W)
            ck = ck[:, S - take:]
            cv = cv[:, S - take:]
            if take < W:
                ck = jnp.pad(ck, ((0, 0), (W - take, 0), (0, 0), (0, 0)))
                cv = jnp.pad(cv, ((0, 0), (W - take, 0), (0, 0), (0, 0)))
            c = (ck.astype(cfg.param_dtype), cv.astype(cfg.param_dtype))
        caches.append(c)
    x = rms_norm(x[:, -1:], params["ln_f"])
    return (x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32))[:, 0], caches


def decode_step(cfg: ArchConfig, params, token, caches, position):
    """Window-capped decode: attention caches are ring buffers of size W."""
    B = token.shape[0]
    x = params["embed"][token][:, None].astype(cfg.param_dtype)
    positions = jnp.broadcast_to(jnp.asarray(position, jnp.int32)[None, None],
                                 (B, 1))
    new_caches = []
    for l, p in enumerate(params["layers"]):
        if "attn" in p:
            # shift cache: always holds the last W keys in order
            h, c = _attn_decode_windowed(cfg, p["attn"], x, position,
                                         caches[l])
            x = x + h
            new_caches.append(c)
        else:
            h, c = _rec_block(cfg, p["rec"], x, state=caches[l])
            x = x + h
            new_caches.append(c)
        f = p["ffn"]
        x = x + ffn(rms_norm(x, f["ln"]), f["w1"], f["w3"], f["w2"], "swiglu")
    x = rms_norm(x, params["ln_f"])
    return (x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32))[:, 0], new_caches