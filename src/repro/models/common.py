"""Shared model substrate: configs, parameter definitions, layer primitives.

Every architecture is a pure-JAX module: `param_defs(cfg)` declares each
parameter's (shape, logical axes); `init_params` materializes them;
`abstract_params` returns ShapeDtypeStructs for the no-allocation dry-run.
Logical axes are mapped to mesh axes by repro.parallel.sharding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]   # logical axis names, len == ndim
    init: str = "normal"              # normal | zeros | ones
    scale: float = 0.02
    dtype: Any = None                 # default -> cfg param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact figures from the assignment table)."""
    name: str
    family: str                   # dense | moe | xlstm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    rope_theta: float = 1e4
    rope_fraction: float = 1.0    # chatglm applies RoPE to half the head dim
    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    d_expert: int = 0             # routed-expert hidden dim (d_ff of an expert)
    moe_every: int = 1            # 1 = every layer is MoE (layer 0 stays dense
                                  # when first_dense is set)
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    # local/global attention (gemma3, recurrentgemma's attn layers)
    window: int = 0               # 0 = full attention
    global_every: int = 0         # gemma3: every Nth layer is global
    # hybrid (recurrentgemma): pattern period 3 -> (rec, rec, attn)
    attn_every: int = 0
    rglru_conv_width: int = 4
    # enc-dec (whisper)
    encoder_layers: int = 0
    cross_attend: bool = False
    # vlm
    num_vision_tokens: int = 0
    mrope_sections: tuple[int, ...] = ()
    # activations / norms
    act: str = "swiglu"           # swiglu | gelu
    logit_softcap: float = 0.0
    # dtypes
    param_dtype: Any = DEFAULT_DTYPE
    # training
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def is_moe_layer(self, layer: int) -> bool:
        if self.num_experts == 0:
            return False
        if layer < self.first_k_dense:
            return False
        return (layer % self.moe_every) == (self.moe_every - 1) \
            if self.moe_every > 1 else True

    def is_global_layer(self, layer: int) -> bool:
        """gemma3: 5 local : 1 global."""
        if self.global_every <= 0:
            return self.window == 0
        return (layer % self.global_every) == (self.global_every - 1)

    def is_attn_layer(self, layer: int) -> bool:
        """recurrentgemma: (rec, rec, attn) repeating."""
        if self.attn_every <= 0:
            return True
        return (layer % self.attn_every) == (self.attn_every - 1)


# ---------------------------------------------------------------------------
# parameter materialization
# ---------------------------------------------------------------------------

def init_params(defs: Any, key: jax.Array, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = d.dtype or dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * d.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs: Any, dtype) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def axes_tree(defs: Any) -> Any:
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# activation sharding hints (set by the launcher; no-op on single-device CPU)
# ---------------------------------------------------------------------------

_ACT_MESH = None


def set_activation_mesh(mesh):
    """Launcher hook: activation with_sharding_constraint hints resolve
    against this mesh ("pod"/"data" = DP+FSDP, "model" = TP). None disables
    all hints (CPU smoke tests)."""
    global _ACT_MESH
    _ACT_MESH = mesh


def constrain(x, *axes):
    """with_sharding_constraint against the launcher mesh; each entry is a
    mesh-axis name, a tuple of names, or None. Axes missing from the mesh or
    not dividing the dim are dropped."""
    if _ACT_MESH is None:
        return x
    mesh = _ACT_MESH
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        axt = (ax,) if isinstance(ax, str) else tuple(ax)
        axt = tuple(a for a in axt if a in mesh.axis_names)
        size = 1
        for a in axt:
            size *= mesh.shape[a]
        if axt and dim % size == 0:
            spec.append(axt if len(axt) > 1 else axt[0])
        else:
            spec.append(None)
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))


DP_AXES = ("pod", "data")


def tp_divides(n: int) -> bool:
    """True when dim n divides the active mesh's "model" axis (False when
    no mesh is set — hints are no-ops then anyway)."""
    if _ACT_MESH is None or "model" not in _ACT_MESH.axis_names:
        return False
    return n % _ACT_MESH.shape["model"] == 0


# ---------------------------------------------------------------------------
# layer primitives (pure jnp; XLA-visible for the roofline — DESIGN.md §3)
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def _rope_angles(positions, dim, theta):
    """positions (...,), dim even -> (..., dim/2) angles."""
    freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * freq


def apply_rope(x, positions, theta=1e4, fraction=1.0):
    """x: (B, S, H, D). Rotates the first `fraction` of D."""
    D = x.shape[-1]
    rd = int(D * fraction)
    rd -= rd % 2
    xr, xp = x[..., :rd], x[..., rd:]
    ang = _rope_angles(positions, rd, theta)          # (B, S, rd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], -1) if rd < D else out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=1e4):
    """qwen2-vl M-RoPE: three position streams over head-dim sections.

    x: (B, S, H, D); positions3: (3, B, S); sections: half-dim split sizes
    summing to D/2.
    """
    D = x.shape[-1]
    half = D // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(0, D, 2, dtype=jnp.float32) / D))
    # choose which position stream drives each frequency band
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)
    pos = positions3.astype(jnp.float32)[sec_id]           # (half, B, S)
    ang = jnp.einsum("hbs,h->bsh", pos, freq)              # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def attention(q, k, v, *, causal=True, window=0, q_offset=0, k_offset=0,
              logit_softcap=0.0):
    """GQA attention, full materialization. q: (B, Sq, H, D); k/v: (B, Sk, G, D).

    `q_offset` positions the queries inside the kv timeline (decode /
    chunked prefill); `k_offset` positions the keys (shift-window caches,
    possibly negative — negative key positions are masked out).
    `window` > 0 limits attention to the last W keys.
    """
    B, Sq, H, D = q.shape
    G = k.shape[2]
    q = q.reshape(B, Sq, G, H // G, D)
    logits = jnp.einsum("bqghd,bkgd->bgqhk", q, k).astype(jnp.float32)
    logits *= 1.0 / math.sqrt(D)
    if logit_softcap > 0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1]) + k_offset
    mask = jnp.broadcast_to(kpos[None, :] >= 0, (Sq, k.shape[1]))
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgqhk,bkgd->bqghd", probs, v)
    return out.reshape(B, Sq, H * D)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      k_offset=0, logit_softcap=0.0, kv_chunk=1024):
    """Flash-style online-softmax attention, lax.scan over KV chunks.

    Peak memory O(Sq * kv_chunk) instead of O(Sq * Sk) — used for the 32k
    prefill / 4k train cells so memory_analysis proves real deployability.

    KV heads are expanded to the full H inside the chunk loop: the score
    slab then carries the H axis (usually TP-divisible) instead of the GQA
    G axis (usually not), so the activation hints can shard it — without
    this the slab replicates across TP (measured 280 GiB/device on
    qwen2-vl-72b prefill_32k).
    """
    B, Sq, H, D = q.shape
    Sk, G = k.shape[1], k.shape[2]
    rep = H // G
    nchunks = -(-Sk // kv_chunk)
    pad = nchunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, kv_chunk, G, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, kv_chunk, G, D).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq) + q_offset
    scale = 1.0 / math.sqrt(D)

    def step(carry, inp):
        m, l, acc = carry
        ci, (kb, vb) = inp
        kbh = jnp.repeat(kb, rep, axis=2)             # (B, chunk, H, D)
        vbh = jnp.repeat(vb, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kbh).astype(jnp.float32) \
            * scale
        logits = constrain(logits, DP_AXES, "model", None, None)
        if logit_softcap > 0:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        kidx = ci * kv_chunk + jnp.arange(kv_chunk)
        kpos = kidx + k_offset
        mask = (kidx[None, :] < Sk) & (kpos[None, :] >= 0)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vbh).astype(jnp.float32)
        acc_new = constrain(acc_new, DP_AXES, "model", None, None)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nchunks), (kc, vc)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out.transpose(0, 2, 1, 3).reshape(B, Sq, H * D)


def ffn(x, w1, w3, w2, act="swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(x @ w1) * (x @ w3)
    else:
        h = jax.nn.gelu(x @ w1)
    return h @ w2


def softmax_xent(logits, labels, vocab: int):
    """Mean cross-entropy; logits (B,S,V) f32, labels (B,S) int32.

    The gold logit is extracted with a masked sum (not take_along_axis):
    with vocab TP-sharded, GSPMD turns this into local partial sums + a
    tiny all-reduce instead of all-gathering the logits.
    """
    logz = jax.nn.logsumexp(logits, -1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), -1)
    return (logz - gold).mean()
