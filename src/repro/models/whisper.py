"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings (B, S_enc, d) directly to the encoder.
Encoder: bidirectional self-attention + GELU FFN. Decoder: causal
self-attention + cross-attention into the encoder output + GELU FFN.

decode_step uses a preallocated self-attention KV cache (the 32k cell is a
stress cache far past Whisper's architectural 448 — noted in DESIGN.md) and
a fixed cross-attention KV computed once from the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (DP_AXES, ArchConfig, ParamDef, apply_rope, attention,
                     chunked_attention, constrain, ffn, rms_norm,
                     softmax_xent)

__all__ = ["param_defs", "loss_fn", "prefill", "decode_step", "forward"]

_FULL_ATTN_LIMIT = 2048 * 2048


def _attn_defs(cfg: ArchConfig, cross=False) -> dict:
    d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wq": ParamDef((d, H * hd), ("embed", "heads")),
        "wk": ParamDef((d, G * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, G * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, d), ("heads", "embed")),
    }


def _ffn_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "w1": ParamDef((d, cfg.d_ff), ("embed", "mlp")),
        "w2": ParamDef((cfg.d_ff, d), ("mlp", "embed")),
    }


def param_defs(cfg: ArchConfig) -> dict:
    enc_layer = lambda: {"attn": _attn_defs(cfg), "ffn": _ffn_defs(cfg)}
    dec_layer = lambda: {"attn": _attn_defs(cfg), "cross": _attn_defs(cfg),
                         "ffn": _ffn_defs(cfg)}
    return {
        # conv frontend is a stub; a learned input projection stands in for it
        "frame_proj": ParamDef((cfg.d_model, cfg.d_model), ("embed", "mlp")),
        "enc_pos": ParamDef((8192, cfg.d_model), (None, "embed")),
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          scale=1.0),
        "encoder": [enc_layer() for _ in range(cfg.encoder_layers)],
        "decoder": [dec_layer() for _ in range(cfg.num_layers)],
        "ln_enc": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "ln_f": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


def _mha(cfg, p, x, kv_x, *, causal, q_offset=0, kv_cache=None,
         write_cache=False):
    """Decoder self-attention (causal=True) carries RoPE — the stand-in for
    Whisper's learned decoder positions (DESIGN.md §Deviations)."""
    B, S, _ = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if causal:
        qpos = jnp.broadcast_to(
            (jnp.arange(S, dtype=jnp.int32) + q_offset)[None], (B, S))
        q = apply_rope(q, qpos, cfg.rope_theta)
    if kv_cache is not None and not write_cache:
        k, v = kv_cache  # fixed cross-attention cache
    else:
        Sk = kv_x.shape[1]
        k = (kv_x @ p["wk"]).reshape(B, Sk, G, hd)
        v = (kv_x @ p["wv"]).reshape(B, Sk, G, hd)
        if causal:
            kpos = jnp.broadcast_to(
                (jnp.arange(Sk, dtype=jnp.int32) + q_offset)[None], (B, Sk))
            k = apply_rope(k, kpos, cfg.rope_theta)
        if kv_cache is not None:  # decode self-attention: write slot
            ck, cv = kv_cache
            k = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                    q_offset, 1)
            v = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                    q_offset, 1)
    fn = attention if S * k.shape[1] <= _FULL_ATTN_LIMIT else chunked_attention
    out = fn(q, k.astype(q.dtype), v.astype(q.dtype), causal=causal,
             q_offset=q_offset)
    return out @ p["wo"], (k, v)


def encode(cfg: ArchConfig, params, frames):
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    x = frames.astype(cfg.param_dtype) @ params["frame_proj"]
    x = x + params["enc_pos"][:x.shape[1]].astype(x.dtype)[None]
    for p in params["encoder"]:
        h, _ = _mha(cfg, p["attn"], rms_norm(x, p["attn"]["ln"]),
                    rms_norm(x, p["attn"]["ln"]), causal=False)
        x = x + h
        x = x + ffn(rms_norm(x, p["ffn"]["ln"]), p["ffn"]["w1"], None,
                    p["ffn"]["w2"], "gelu")
    return rms_norm(x, params["ln_enc"])


def _decoder_block(cfg, p, x, enc, q_offset=0, self_cache=None,
                   cross_cache=None):
    h, new_self = _mha(cfg, p["attn"], rms_norm(x, p["attn"]["ln"]),
                       rms_norm(x, p["attn"]["ln"]), causal=True,
                       q_offset=q_offset, kv_cache=self_cache,
                       write_cache=self_cache is not None)
    x = x + h
    if cross_cache is not None:
        h, _ = _mha(cfg, p["cross"], rms_norm(x, p["cross"]["ln"]), None,
                    causal=False, kv_cache=cross_cache)
    else:
        h, cross_cache = _mha(cfg, p["cross"], rms_norm(x, p["cross"]["ln"]),
                              enc, causal=False)
    x = x + h
    x = x + ffn(rms_norm(x, p["ffn"]["ln"]), p["ffn"]["w1"], None,
                p["ffn"]["w2"], "gelu")
    return x, new_self, cross_cache


def forward(cfg: ArchConfig, params, batch, *, remat: bool = True):
    """Training forward: frames (B, S_enc, d) + tokens (B, S_dec)."""
    enc = encode(cfg, params, batch["frames"])
    x = params["embed"][batch["tokens"]].astype(cfg.param_dtype)
    for p in params["decoder"]:
        if remat:
            x = jax.checkpoint(
                lambda p_, x_, e_: _decoder_block(cfg, p_, x_, e_)[0])(p, x, enc)
        else:
            x, _, _ = _decoder_block(cfg, p, x, enc)
    x = rms_norm(x, params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return constrain(logits, DP_AXES, None, "model")


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits = forward(cfg, params, batch, remat=remat)
    return softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab_size)


def prefill(cfg: ArchConfig, params, batch):
    """Encode + run the decoder prompt, returning (logits, caches) where
    caches = list of (self_k, self_v, cross_k, cross_v)."""
    enc = encode(cfg, params, batch["frames"])
    x = params["embed"][batch["tokens"]].astype(cfg.param_dtype)
    caches = []
    for p in params["decoder"]:
        x, self_kv, cross_kv = _decoder_block(cfg, p, x, enc)
        caches.append((self_kv[0], self_kv[1], cross_kv[0], cross_kv[1]))
    x = rms_norm(x[:, -1:], params["ln_f"])
    return (x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32))[:, 0], caches


def decode_step(cfg: ArchConfig, params, token, caches, position):
    """caches: list of (self_k, self_v, cross_k, cross_v); self caches are
    preallocated (B, S_max, G, hd)."""
    x = params["embed"][token][:, None].astype(cfg.param_dtype)
    new_caches = []
    for p, (sk, sv, ck, cv) in zip(params["decoder"], caches):
        x, (sk2, sv2), _ = _decoder_block(cfg, p, x, None, q_offset=position,
                                          self_cache=(sk, sv),
                                          cross_cache=(ck, cv))
        new_caches.append((sk2, sv2, ck, cv))
    x = rms_norm(x, params["ln_f"])
    return (x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32))[:, 0], new_caches