"""Decoder-only transformer backbone: dense, MoE, and VLM variants.

Covers kimi-k2-1t-a32b, qwen2-moe-a2.7b, chatglm3-6b, phi4-mini-3.8b,
mistral-nemo-12b, gemma3-4b (5:1 local:global windows) and the qwen2-vl-72b
backbone (M-RoPE + stub vision prefix).

Everything is pure jnp so the dry-run's cost_analysis sees every FLOP
(DESIGN.md §3). Layers are materialized as per-layer parameter lists and
applied with a Python loop + optional jax.checkpoint — unrolled HLO makes
the roofline exact (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .common import (DP_AXES, ArchConfig, ParamDef, apply_mrope, apply_rope,
                     attention, chunked_attention, constrain, ffn, rms_norm,
                     softmax_xent)
from .moe import moe_ffn_defs, moe_ffn_apply

# attention score materialization is capped; larger S*K uses the chunked
# (online-softmax) path. 2048^2 keeps the score slab shardable even when
# kv_heads < TP width (GQA scores carry the G axis, which often can't take
# the model axis; the chunk scan bounds the live slab instead).
_FULL_ATTN_LIMIT = 2048 * 2048


def _attn_defs(cfg: ArchConfig) -> dict:
    d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return {
        "wq": ParamDef((d, H * hd), ("embed", "heads")),
        "wk": ParamDef((d, G * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, G * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, d), ("heads", "embed")),
    }


def _ffn_defs(cfg: ArchConfig, d_ff: int) -> dict:
    d = cfg.d_model
    if cfg.act == "swiglu":
        return {
            "w1": ParamDef((d, d_ff), ("embed", "mlp")),
            "w3": ParamDef((d, d_ff), ("embed", "mlp")),
            "w2": ParamDef((d_ff, d), ("mlp", "embed")),
        }
    return {
        "w1": ParamDef((d, d_ff), ("embed", "mlp")),
        "w2": ParamDef((d_ff, d), ("mlp", "embed")),
    }


def layer_defs(cfg: ArchConfig, layer: int) -> dict:
    out = {
        "ln1": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "ln2": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "attn": _attn_defs(cfg),
    }
    if cfg.is_moe_layer(layer):
        out["moe"] = moe_ffn_defs(cfg)
    else:
        out["ffn"] = _ffn_defs(cfg, cfg.d_ff)
    return out


def param_defs(cfg: ArchConfig) -> dict:
    return {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          scale=1.0),
        "layers": [layer_defs(cfg, l) for l in range(cfg.num_layers)],
        "ln_f": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# scan-layers (stacked) layout — homogeneous-layer archs (MoE giants)
#
# Unrolled HLO at 61 MoE layers takes XLA's SPMD partitioner an hour on this
# host; the production program scans one stacked layer block instead
# (compile time ~L/period x smaller). Roofline FLOPs for scanned cells use
# the hybrid accounting in launch/dryrun.py (scan program counts the body
# once; a standalone per-layer jit supplies the per-iteration cost).
# ---------------------------------------------------------------------------

def _stack_defs(d, n):
    return jax.tree.map(
        lambda pd: ParamDef((n,) + pd.shape, (None,) + pd.axes,
                            init=pd.init, scale=pd.scale, dtype=pd.dtype),
        d, is_leaf=lambda x: isinstance(x, ParamDef))


def stacked_param_defs(cfg: ArchConfig) -> dict:
    """first_k_dense layers stay unrolled; the homogeneous tail is stacked.
    Requires every remaining layer to share structure."""
    kinds = [cfg.is_moe_layer(l) for l in range(cfg.first_k_dense,
                                                cfg.num_layers)]
    assert all(k == kinds[0] for k in kinds), \
        "stacked layout needs a homogeneous layer tail"
    n_tail = cfg.num_layers - cfg.first_k_dense
    return {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          scale=1.0),
        "head_layers": [layer_defs(cfg, l) for l in range(cfg.first_k_dense)],
        "stack": _stack_defs(layer_defs(cfg, cfg.first_k_dense), n_tail),
        "ln_f": ParamDef((cfg.d_model,), ("embed",), init="ones"),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }


def forward_scanned(cfg: ArchConfig, params, batch, *, remat: bool = True):
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = (_mrope_positions(cfg, B, S) if cfg.mrope_sections
                 else _positions(cfg, B, S))
    for l, p in enumerate(params["head_layers"]):
        x, _ = _block(cfg, p, x, positions, layer=l)
    rep = cfg.first_k_dense  # representative layer index for the tail

    def body(x_, p_):
        fn = lambda pp, xx: _block(cfg, pp, xx, positions, layer=rep)[0]
        if remat:
            fn = jax.checkpoint(fn)
        return fn(p_, x_), None

    x, _ = jax.lax.scan(body, x, params["stack"])
    x = rms_norm(x, params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return constrain(logits, DP_AXES, None, "model")


def loss_fn_scanned(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits = forward_scanned(cfg, params, batch, remat=remat)
    return softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab_size)


def layer_fwdbwd_probe(cfg: ArchConfig, layer: int):
    """Standalone (params, x, positions) -> grads for ONE layer — jitted by
    the dry-run to recover per-layer FLOPs/bytes for scanned programs."""
    def fn(p, x, positions):
        def f(p_, x_):
            return (_block(cfg, p_, x_, positions, layer=layer)[0]
                    .astype(jnp.float32) ** 2).sum()
        g = jax.grad(f, argnums=(0, 1))(p, x)
        return g
    return fn


def params_to_stacked(cfg: ArchConfig, params):
    """Per-layer checkpoint layout -> stacked layout (and back below)."""
    tail = params["layers"][cfg.first_k_dense:]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *tail)
    return {"embed": params["embed"],
            "head_layers": params["layers"][:cfg.first_k_dense],
            "stack": stack, "ln_f": params["ln_f"],
            "unembed": params["unembed"]}


def stacked_to_params(cfg: ArchConfig, sp):
    n = cfg.num_layers - cfg.first_k_dense
    tail = [jax.tree.map(lambda x: x[i], sp["stack"]) for i in range(n)]
    return {"embed": sp["embed"],
            "layers": list(sp["head_layers"]) + tail,
            "ln_f": sp["ln_f"], "unembed": sp["unembed"]}


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _positions(cfg: ArchConfig, B: int, S: int, offset=0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (B, S))


def _mrope_positions(cfg: ArchConfig, B: int, S: int, offset=0):
    """Stub M-RoPE ids: vision prefix gets a (t=0, h, w) grid, text advances
    all three streams together (qwen2-vl convention, frontend stubbed)."""
    P = cfg.num_vision_tokens
    side = max(1, int(P ** 0.5))
    t_ids = jnp.where(jnp.arange(S) < P, 0, jnp.arange(S) - P + 1)
    h_ids = jnp.where(jnp.arange(S) < P, jnp.arange(S) // side,
                      jnp.arange(S) - P + 1)
    w_ids = jnp.where(jnp.arange(S) < P, jnp.arange(S) % side,
                      jnp.arange(S) - P + 1)
    pos3 = jnp.stack([t_ids, h_ids, w_ids]).astype(jnp.int32) + offset
    return jnp.broadcast_to(pos3[:, None, :], (3, B, S))


def _rotate(cfg: ArchConfig, q, k, positions):
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k


def _layer_window(cfg: ArchConfig, layer: int) -> int:
    """Effective sliding window for this layer (0 = full attention)."""
    if cfg.global_every > 0:  # gemma3 local:global pattern
        return 0 if cfg.is_global_layer(layer) else cfg.window
    return cfg.window


def _self_attn(cfg: ArchConfig, p, x, positions, *, layer: int, q_offset=0,
               kv_cache=None, window_override=None):
    """Returns (out, new_kv). kv_cache: (k, v) with layout (B, Sk, G, hd).

    Decode caches shorter than the timeline are *shift* caches (local
    windowed layers — §Perf gemma3 long_500k iteration): the oldest key
    drops off the front and keys live at absolute positions
    q_offset-W+1..q_offset (k_offset masks the unfilled prefix).
    """
    B, S, _ = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, G, hd)
    v = (x @ p["wv"]).reshape(B, S, G, hd)
    q, k = _rotate(cfg, q, k, positions)
    window = _layer_window(cfg, layer) if window_override is None \
        else window_override
    k_offset = 0
    if kv_cache is not None:
        ck, cv = kv_cache
        W = ck.shape[1]
        if S == 1 and window > 0 and W <= window:
            # shift cache: holds exactly the last W roped keys in order
            ck = jnp.concatenate([ck[:, 1:], k.astype(ck.dtype)], axis=1)
            cv = jnp.concatenate([cv[:, 1:], v.astype(cv.dtype)], axis=1)
            k_offset = jnp.asarray(q_offset, jnp.int32) - W + 1
        else:
            # full cache: write at q_offset (preallocated timeline)
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), q_offset, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), q_offset, 1)
        k, v = ck, cv
        new_cache = (ck, cv)
    else:
        new_cache = (k, v)
    attn_fn = attention if q.shape[1] * k.shape[1] <= _FULL_ATTN_LIMIT \
        else chunked_attention
    out = attn_fn(q, k.astype(q.dtype), v.astype(q.dtype), causal=True,
                  window=window, q_offset=q_offset, k_offset=k_offset,
                  logit_softcap=cfg.logit_softcap)
    return out @ p["wo"], new_cache


def _block(cfg: ArchConfig, p, x, positions, *, layer: int, q_offset=0,
           kv_cache=None):
    h, new_cache = _self_attn(cfg, p["attn"], rms_norm(x, p["ln1"]), positions,
                              layer=layer, q_offset=q_offset, kv_cache=kv_cache)
    x = x + h
    hin = rms_norm(x, p["ln2"])
    if "moe" in p:
        x = x + moe_ffn_apply(cfg, p["moe"], hin)
    else:
        x = x + ffn(hin, p["ffn"]["w1"], p["ffn"].get("w3"),
                    p["ffn"]["w2"], cfg.act)
    return x, new_cache


def _embed_inputs(cfg: ArchConfig, params, batch):
    x = params["embed"][batch["tokens"]].astype(cfg.param_dtype)
    x = constrain(x, DP_AXES, None, None)
    if cfg.num_vision_tokens > 0:
        P = cfg.num_vision_tokens
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x[:, P:]], axis=1)
    return x


def forward(cfg: ArchConfig, params, batch, *, remat: bool = True):
    """Full-sequence forward -> logits (B, S, V) in f32."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = (_mrope_positions(cfg, B, S) if cfg.mrope_sections
                 else _positions(cfg, B, S))

    for l, p in enumerate(params["layers"]):
        blk = functools.partial(_block, cfg, layer=l)
        if remat:
            blk = jax.checkpoint(
                lambda p_, x_, pos_, _l=l: _block(cfg, p_, x_, pos_, layer=_l)[0])
            x = blk(p, x, positions)
        else:
            x, _ = _block(cfg, p, x, positions, layer=l)
    x = rms_norm(x, params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return constrain(logits, DP_AXES, None, "model")


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits = forward(cfg, params, batch, remat=remat)
    return softmax_xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab_size)


def prefill(cfg: ArchConfig, params, batch):
    """Forward + return per-layer KV caches and last-position logits.

    Windowed (local) layers keep only their last W keys as a shift cache —
    the 5:1 local:global memory win for gemma3 (DESIGN.md §6)."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = (_mrope_positions(cfg, B, S) if cfg.mrope_sections
                 else _positions(cfg, B, S))
    from .common import tp_divides
    tp_on_heads = tp_divides(cfg.num_kv_heads)
    caches = []
    for l, p in enumerate(params["layers"]):
        x, kv = _block(cfg, p, x, positions, layer=l)
        W = _layer_window(cfg, l)
        if W and S >= W:
            kv = (kv[0][:, S - W:], kv[1][:, S - W:])
        elif W:
            kv = (jnp.pad(kv[0], ((0, 0), (W - S, 0), (0, 0), (0, 0))),
                  jnp.pad(kv[1], ((0, 0), (W - S, 0), (0, 0), (0, 0))))
        if not W:
            # pin the per-layer cache to its serving layout immediately —
            # without this XLA holds all L layers' caches at the producer
            # sharding (measured 280 GiB/device on vl-72b prefill_32k)
            if tp_on_heads:
                kv = (constrain(kv[0], DP_AXES, None, "model", None),
                      constrain(kv[1], DP_AXES, None, "model", None))
            else:
                kv = (constrain(kv[0], DP_AXES, "model", None, None),
                      constrain(kv[1], DP_AXES, "model", None, None))
        caches.append(kv)
    x = rms_norm(x[:, -1:], params["ln_f"])
    logits = x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return logits[:, 0], caches


def decode_step(cfg: ArchConfig, params, token, caches, position: jax.Array):
    """One decode step against preallocated KV caches.

    token: (B,) int32; caches: list of (k, v) each (B, S_max, G, hd);
    position: scalar int32 current write index.
    Returns (logits (B, V), new caches).
    """
    B = token.shape[0]
    x = params["embed"][token][:, None].astype(cfg.param_dtype)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(
            jnp.asarray(position, jnp.int32)[None, None, None], (3, B, 1))
        positions = pos3
    else:
        positions = jnp.broadcast_to(
            jnp.asarray(position, jnp.int32)[None, None], (B, 1))
    new_caches = []
    for l, p in enumerate(params["layers"]):
        x_n = rms_norm(x, p["ln1"])
        h, kv = _self_attn(cfg, p["attn"], x_n, positions, layer=l,
                           q_offset=position, kv_cache=caches[l])
        x = x + h
        hin = rms_norm(x, p["ln2"])
        if "moe" in p:
            x = x + moe_ffn_apply(cfg, p["moe"], hin)
        else:
            w3 = p["ffn"].get("w3")
            x = x + ffn(hin, p["ffn"]["w1"], w3, p["ffn"]["w2"], cfg.act)
        new_caches.append(kv)
    x = rms_norm(x, params["ln_f"])
    logits = (x.astype(jnp.float32) @ params["unembed"].astype(jnp.float32))
    return logits[:, 0], new_caches