"""Unified model API: one ModelApi per architecture family.

Every family exposes:
  param_defs / init_params / abstract_params / axes  — parameters
  loss_fn(params, batch)                              — training loss
  prefill(params, batch) -> (logits, caches)          — inference prefill
  decode_step(params, token, caches, position)        — one-token decode
  input_specs(shape_kind, ...)                        — ShapeDtypeStructs
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import rglru, transformer, whisper, xlstm
from .common import ArchConfig, abstract_params, axes_tree, init_params

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "xlstm": xlstm,
    "hybrid": rglru,
    "encdec": whisper,
}


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    mod: Any

    # ---- parameters -------------------------------------------------------
    def param_defs(self):
        return self.mod.param_defs(self.cfg)

    def init(self, key):
        return init_params(self.param_defs(), key, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.param_defs(), self.cfg.param_dtype)

    def axes(self):
        return axes_tree(self.param_defs())

    # ---- steps ------------------------------------------------------------
    def loss_fn(self, params, batch, remat: bool = True):
        return self.mod.loss_fn(self.cfg, params, batch, remat=remat)

    def forward(self, params, batch, remat: bool = False):
        return self.mod.forward(self.cfg, params, batch, remat=remat)

    def prefill(self, params, batch):
        return self.mod.prefill(self.cfg, params, batch)

    def decode_step(self, params, token, caches, position):
        return self.mod.decode_step(self.cfg, params, token, caches, position)

    # ---- inputs ------------------------------------------------------------
    def train_inputs(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        }
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.num_vision_tokens, cfg.d_model),
                cfg.param_dtype)
        if cfg.family == "encdec":
            # frame embeddings replace tokens on the encoder side (stub)
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch_size, min(seq_len, 4096), cfg.d_model), cfg.param_dtype)
        return specs

    def prefill_inputs(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        specs = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len),
                                                jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (batch_size, cfg.num_vision_tokens, cfg.d_model),
                cfg.param_dtype)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch_size, 1500, cfg.d_model), cfg.param_dtype)
        return specs

    # ---- decode cache specs -------------------------------------------------
    def abstract_caches(self, batch_size: int, max_seq: int):
        """ShapeDtypeStructs for the decode state at a given cache length."""
        cfg = self.cfg
        dt = cfg.param_dtype
        G, hd, d = cfg.num_kv_heads, cfg.hd, cfg.d_model

        def kv(length):
            s = jax.ShapeDtypeStruct((batch_size, length, G, hd), dt)
            return (s, s)

        caches = []
        if cfg.family == "xlstm":
            H = cfg.num_heads
            hd2 = d // H
            for l in range(cfg.num_layers):
                if l % 2 == 0:
                    caches.append((
                        jax.ShapeDtypeStruct((batch_size, H, hd2, hd2), dt),
                        jax.ShapeDtypeStruct((batch_size, H, hd2), dt),
                        jax.ShapeDtypeStruct((batch_size, H), jnp.float32)))
                else:
                    caches.append((
                        jax.ShapeDtypeStruct((batch_size, d), jnp.float32),
                        jax.ShapeDtypeStruct((batch_size, d), jnp.float32)))
        elif cfg.family == "hybrid":
            w = cfg.rglru_conv_width
            kv_len = min(max_seq, cfg.window) if cfg.window else max_seq
            for l in range(cfg.num_layers):
                if cfg.is_attn_layer(l):
                    caches.append(kv(kv_len))
                else:
                    caches.append((
                        jax.ShapeDtypeStruct((batch_size, d), jnp.float32),
                        jax.ShapeDtypeStruct((batch_size, w - 1, d), dt)))
        elif cfg.family == "encdec":
            for _ in range(cfg.num_layers):
                sk, sv = kv(max_seq)
                ck, cv = kv(1500)
                caches.append((sk, sv, ck, cv))
        else:
            for l in range(cfg.num_layers):
                if cfg.window and (cfg.global_every <= 0
                                   or not cfg.is_global_layer(l)):
                    caches.append(kv(min(max_seq, cfg.window)))
                else:
                    caches.append(kv(max_seq))
        return caches


def get_model(cfg: ArchConfig) -> ModelApi:
    return ModelApi(cfg, _FAMILY_MODULES[cfg.family])