# Billing-faithful egress layer: the cloud store simulator (eq. 1 metering,
# per-consumer attribution) and the deployable dollar-aware cache with its
# offline-exact audit. The online governance layer (repro.online) subscribes
# to EgressCache's AccessEvent stream from above.
from .store import BillingMeter, ObjectStore
from .cache import (ONLINE_POLICIES, AccessEvent, AdmissionController,
                    AuditReport, EgressCache)

__all__ = [
    "BillingMeter", "ObjectStore", "ONLINE_POLICIES", "AccessEvent",
    "AdmissionController", "AuditReport", "EgressCache",
]
