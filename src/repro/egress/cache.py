"""Online egress cache: the paper's policies as a deployable component.

Sits between compute and the ObjectStore. Pluggable policy (LRU / LFU /
GDS / GDSF — the online subset of core/policies.py), byte-capacity budget,
billing-faithful accounting, and an `audit()` that replays the observed
access trace against the exact offline dollar-optimum (core/opt_exact,
cost-FOO) — the framework-native use of the paper's reference.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core import (PRICE_VECTORS, Trace, cost_foo, exact_opt_uniform,
                        heterogeneity, regret)
from repro.core.pricing import PriceVector
from .store import ObjectStore

__all__ = ["EgressCache", "AuditReport"]


@dataclasses.dataclass
class AuditReport:
    policy: str
    observed_dollars: float
    opt_dollars_lower: float     # exact (uniform) or cost-FOO lower bound
    opt_dollars_upper: float
    dollar_regret: float         # vs the lower bound (conservative)
    heterogeneity: float
    crossover_bytes: float
    mean_object_bytes: float
    requests: int
    hit_rate: float

    def summary(self) -> str:
        return (f"[egress audit] policy={self.policy} "
                f"$={self.observed_dollars:.6f} "
                f"OPT in [{self.opt_dollars_lower:.6f}, "
                f"{self.opt_dollars_upper:.6f}] "
                f"regret={self.dollar_regret:.3f} H={self.heterogeneity:.3f} "
                f"s*={self.crossover_bytes:.0f}B "
                f"mean_obj={self.mean_object_bytes:.0f}B "
                f"hit_rate={self.hit_rate:.3f}")


class EgressCache:
    """Byte-budgeted local cache over an ObjectStore, dollar-aware."""

    def __init__(self, store: ObjectStore, capacity_bytes: float,
                 policy: str = "gdsf"):
        assert policy in ("lru", "lfu", "gds", "gdsf"), policy
        self.store = store
        self.capacity = float(capacity_bytes)
        self.policy = policy
        self.used = 0.0
        self._data: dict[str, bytes] = {}
        self._prio: dict[str, tuple[float, int]] = {}
        self._heap: list[tuple[float, int, str]] = []
        self._freq: dict[str, int] = {}
        self._inflation = 0.0
        self._clock = 0
        # access log for offline audit
        self._trace_keys: list[str] = []
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def _miss_cost(self, nbytes: int) -> float:
        return float(self.store.meter.price.miss_cost(nbytes))

    def _priority(self, key: str, nbytes: int) -> float:
        dens = self._miss_cost(nbytes) / max(nbytes, 1)
        if self.policy == "lru":
            return float(self._clock)
        if self.policy == "lfu":
            return float(self._freq[key])
        if self.policy == "gds":
            return self._inflation + dens
        return self._inflation + self._freq[key] * dens  # gdsf

    def _touch(self, key: str, nbytes: int):
        pr = self._priority(key, nbytes)
        self._prio[key] = (pr, self._clock)
        heapq.heappush(self._heap, (pr, self._clock, key))

    def _evict_until_fits(self, need: float):
        while self.used + need > self.capacity and self._prio:
            pr, tt, key = heapq.heappop(self._heap)
            if self._prio.get(key) != (pr, tt):
                continue
            del self._prio[key]
            data = self._data.pop(key)
            self.used -= len(data)
            if self.policy in ("gds", "gdsf"):
                self._inflation = pr

    # ------------------------------------------------------------------
    def get(self, key: str) -> bytes:
        self._clock += 1
        self._trace_keys.append(key)
        self._freq[key] = self._freq.get(key, 0) + 1
        if key in self._data:
            self.hits += 1
            self._touch(key, len(self._data[key]))
            return self._data[key]
        self.misses += 1
        data = self.store.get(key)   # billed fetch
        if len(data) <= self.capacity:
            self._evict_until_fits(len(data))
            self._data[key] = data
            self.used += len(data)
            self._touch(key, len(data))
        return data

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    # ------------------------------------------------------------------
    def audit(self, budget_pages: Optional[int] = None) -> AuditReport:
        """Replay the observed trace against the exact offline reference."""
        keys = self._trace_keys
        uniq = {k: i for i, k in enumerate(dict.fromkeys(keys))}
        ids = np.array([uniq[k] for k in keys], np.int32)
        sizes = np.zeros(len(uniq))
        for k, i in uniq.items():
            sizes[i] = self.store.size_of(k)
        costs = self.store.meter.price.miss_cost(sizes)
        tr = Trace(ids=ids, sizes=sizes, name="egress_audit")
        uniform = len(set(sizes.tolist())) == 1
        if uniform:
            B = budget_pages or max(1, int(self.capacity // sizes[0]))
            o = exact_opt_uniform(ids, costs, B)
            lower = upper = o.dollars
        else:
            r = cost_foo(tr, costs, self.capacity)
            lower, upper = r.lower, r.upper
        # the meter billed exactly this cache's misses
        observed = float(self.store.meter.dollars)
        return AuditReport(
            policy=self.policy, observed_dollars=observed,
            opt_dollars_lower=lower, opt_dollars_upper=upper,
            dollar_regret=regret(observed, lower),
            heterogeneity=heterogeneity(ids, costs),
            crossover_bytes=self.store.meter.price.crossover_bytes,
            mean_object_bytes=float(sizes[ids].mean()),
            requests=len(keys), hit_rate=self.hit_rate)