"""Online egress cache: the paper's policies as a deployable component.

Sits between compute and the ObjectStore. Pluggable policy (LRU / LFU /
GDS / GDSF — the online subset of core/policies.py), byte-capacity budget,
billing-faithful accounting, and an `audit()` that replays the observed
access trace against the exact offline dollar-optimum (core/opt_exact,
cost-FOO) — the framework-native use of the paper's reference.

Governance surface (DESIGN.md §8): every access emits an `AccessEvent` to
registered listeners (the shadow panel / windowed audit / metrics of
`repro.online` subscribe here without touching the billed path);
`set_policy` hot-swaps the replacement policy in place, preserving cache
contents so a swap never re-bills; an optional admission controller can
veto insertions (fetch-through, the s*-aware bypass of eq. 3).

Observability surface (DESIGN.md §9), all duck-typed so this layer never
imports `repro.obs`: `tracer` gets one `cache.get` span per access (the
billed `store.get` span nests inside it on a miss); `events` gets one
decision event per hit/miss/admit/reject/evict/policy_swap with its
dollar delta; `metrics.observe_hist` (when present) gets log-bucketed
object-size (centered on s*) and per-GET-dollar histograms. All three
default to None and cost one branch when absent.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional, Protocol

import numpy as np

from repro.core import (Trace, cost_foo, exact_opt_uniform,
                        exact_opt_uniform_sweep, heterogeneity, regret)
from .store import ObjectStore

__all__ = ["EgressCache", "AuditReport", "AccessEvent", "AdmissionController",
           "ONLINE_POLICIES"]

ONLINE_POLICIES = ("lru", "lfu", "gds", "gdsf")

_cache_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class AccessEvent:
    """One cache access, as seen by governance listeners (shadow panel,
    windowed audit, metrics). Carries everything a metadata-only replica
    needs — no object bytes, no store traffic.

    `event_time` is the access's position on the *event-time* axis (a fleet
    replaying a partitioned trace stamps the global trace index here, so
    windows align across hosts despite skewed arrival); it defaults to the
    cache-local clock when the caller doesn't provide one."""
    key: str
    nbytes: int
    hit: bool
    miss_cost: float   # c = f + s*e at the price in effect NOW
    policy: str
    clock: int
    event_time: float = -1.0   # filled with float(clock) when not supplied


class AdmissionController(Protocol):
    def admit(self, key: str, nbytes: int, freq: int) -> bool:
        """True = insert into the cache; False = serve fetch-through."""
        ...


@dataclasses.dataclass
class AuditReport:
    policy: str
    observed_dollars: float
    opt_dollars_lower: float     # exact (uniform) or cost-FOO lower bound
    opt_dollars_upper: float
    dollar_regret: float         # vs the lower bound (conservative)
    heterogeneity: float
    crossover_bytes: float
    mean_object_bytes: float
    requests: int
    hit_rate: float
    # exact OPT-dollars per budget when a grid was requested (uniform sizes):
    opt_by_budget: Optional[dict[int, float]] = None

    def summary(self) -> str:
        return (f"[egress audit] policy={self.policy} "
                f"$={self.observed_dollars:.6f} "
                f"OPT in [{self.opt_dollars_lower:.6f}, "
                f"{self.opt_dollars_upper:.6f}] "
                f"regret={self.dollar_regret:.3f} H={self.heterogeneity:.3f} "
                f"s*={self.crossover_bytes:.0f}B "
                f"mean_obj={self.mean_object_bytes:.0f}B "
                f"hit_rate={self.hit_rate:.3f}")


class EgressCache:
    """Byte-budgeted local cache over an ObjectStore, dollar-aware.

    Bills through its OWN consumer meter (`store.meter_for(consumer)`), so
    `audit()` scores exactly the misses this cache caused — other consumers
    sharing the store (warm-up puts, sibling caches) never pollute it.
    """

    def __init__(self, store: ObjectStore, capacity_bytes: float,
                 policy: str = "gdsf", consumer: Optional[str] = None,
                 admission: Optional[AdmissionController] = None,
                 metrics=None, tracer=None, events=None):
        assert policy in ONLINE_POLICIES, policy
        self.store = store
        self.capacity = float(capacity_bytes)
        self.policy = policy
        self.consumer = consumer or f"egress_cache_{next(_cache_counter)}"
        self.meter = store.meter_for(self.consumer)
        self.admission = admission
        self.metrics = metrics           # duck-typed: .inc(name, value=1)
        self.tracer = tracer             # duck-typed: .span(name, cat, **a)
        self.events = events             # duck-typed: .record(kind, ...)
        # precomputed publishing surface (hot path stays branch-cheap)
        self._observe_hist = getattr(metrics, "observe_hist", None)
        self._m_hits = f"egress.{self.consumer}.hits"
        self._m_misses = f"egress.{self.consumer}.misses"
        self._m_bytes = f"egress.{self.consumer}.bytes_fetched"
        self._m_size_hist = f"egress.{self.consumer}.object_bytes"
        self._m_dollar_hist = f"egress.{self.consumer}.get_dollars"
        # size buckets centered on s* at attach time (octaves of 2; the s*
        # boundary itself is a bucket bound, so counts at/below it are the
        # fee-dominated accesses)
        sstar = store.price.crossover_bytes
        self._size_bounds = [sstar * 2.0 ** k for k in range(-8, 9)]
        self.used = 0.0
        self._data: dict[str, bytes] = {}
        self._prio: dict[str, tuple[float, int]] = {}
        self._heap: list[tuple[float, int, str]] = []
        self._freq: dict[str, int] = {}
        self._inflation = 0.0
        self._clock = 0
        self._listeners: list[Callable[[AccessEvent], None]] = []
        # access log for offline audit
        self._trace_keys: list[str] = []
        self.hits = 0
        self.misses = 0
        self.policy_swaps = 0
        self.bypasses = 0

    # ------------------------------------------------------------------
    def add_listener(self, fn: Callable[[AccessEvent], None]) -> None:
        self._listeners.append(fn)

    def _miss_cost(self, nbytes: int) -> float:
        # scalar fast path; reads store.price on every call so a mid-stream
        # `set_price` reprices immediately (bit-equal to miss_cost(nbytes))
        return self.store.price.miss_cost_scalar(nbytes)

    def _priority(self, key: str, nbytes: int) -> float:
        dens = self._miss_cost(nbytes) / max(nbytes, 1)
        if self.policy == "lru":
            return float(self._clock)
        if self.policy == "lfu":
            return float(self._freq[key])
        if self.policy == "gds":
            return self._inflation + dens
        return self._inflation + self._freq[key] * dens  # gdsf

    def _touch(self, key: str, nbytes: int):
        pr = self._priority(key, nbytes)
        self._prio[key] = (pr, self._clock)
        heapq.heappush(self._heap, (pr, self._clock, key))

    def _evict_until_fits(self, need: float):
        while self.used + need > self.capacity and self._prio:
            pr, tt, key = heapq.heappop(self._heap)
            if self._prio.get(key) != (pr, tt):
                continue
            del self._prio[key]
            data = self._data.pop(key)
            self.used -= len(data)
            if self.policy in ("gds", "gdsf"):
                self._inflation = pr
            if self.events is not None:
                # bills nothing now; at stake = the re-fetch cost if touched
                self.events.record("evict", key, len(data), 0.0,
                                   self._miss_cost(len(data)), self._clock,
                                   self.policy)

    # ------------------------------------------------------------------
    def set_policy(self, policy: str) -> None:
        """Hot-swap the replacement policy, preserving cache contents.

        Priorities of resident objects are recomputed under the new policy
        and the heap rebuilt; nothing is evicted or refetched, so the swap
        itself bills $0 (asserted in tests/test_serve_billing.py)."""
        assert policy in ONLINE_POLICIES, policy
        if policy == self.policy:
            return
        self.policy = policy
        self._inflation = 0.0
        self._heap = []
        for key, data in self._data.items():
            pr = self._priority(key, len(data))
            touch = self._prio[key][1]
            self._prio[key] = (pr, touch)
            heapq.heappush(self._heap, (pr, touch, key))
        self.policy_swaps += 1
        if self.metrics is not None:
            self.metrics.inc(f"egress.{self.consumer}.policy_swaps")
        if self.events is not None:
            self.events.record("policy_swap", "", 0, 0.0, 0.0, self._clock,
                               policy)

    # ------------------------------------------------------------------
    def get(self, key: str, event_time: Optional[float] = None) -> bytes:
        t = self.tracer
        if not t:
            return self._lookup(key, event_time)
        sp = t.begin("cache.get", "cache")
        try:
            h0 = self.hits
            data = self._lookup(key, event_time)
            sp.attrs = {"key": key, "bytes": len(data),
                        "hit": self.hits > h0, "policy": self.policy}
            return data
        finally:
            t.end(sp)

    def _lookup(self, key: str, event_time: Optional[float] = None) -> bytes:
        self._clock += 1
        self._trace_keys.append(key)
        self._freq[key] = self._freq.get(key, 0) + 1
        if key in self._data:
            self.hits += 1
            data = self._data[key]
            self._touch(key, len(data))
            self._emit(key, len(data), True, event_time)
            return data
        self.misses += 1
        data = self.store.get(key, consumer=self.consumer)   # billed fetch
        nbytes = len(data)
        admit = nbytes <= self.capacity
        if admit and self.admission is not None:
            admit = self.admission.admit(key, nbytes, self._freq[key])
            if not admit:
                self.bypasses += 1
        if admit:
            self._evict_until_fits(nbytes)
            self._data[key] = data
            self.used += nbytes
            self._touch(key, nbytes)
        self._emit(key, nbytes, False, event_time)
        if self.events is not None:
            self.events.record("admit" if admit else "reject", key, nbytes,
                               0.0, self._miss_cost(nbytes), self._clock,
                               self.policy)
        return data

    def _emit(self, key: str, nbytes: int, hit: bool,
              event_time: Optional[float] = None) -> None:
        mc = None
        if self.metrics is not None:
            self.metrics.inc(self._m_hits if hit else self._m_misses)
            if not hit:
                self.metrics.inc(self._m_bytes, nbytes)
            if self._observe_hist is not None:
                self._observe_hist(self._m_size_hist, nbytes,
                                   bounds=self._size_bounds)
                if not hit:
                    mc = self._miss_cost(nbytes)
                    self._observe_hist(self._m_dollar_hist, mc)
        if self.events is not None or self._listeners:
            if mc is None:
                mc = self._miss_cost(nbytes)
            if self.events is not None:
                self.events.record("hit" if hit else "miss", key, nbytes,
                                   0.0 if hit else mc, mc, self._clock,
                                   self.policy)
            if self._listeners:
                ev = AccessEvent(key, nbytes, hit, mc, self.policy,
                                 self._clock,
                                 float(self._clock) if event_time is None
                                 else float(event_time))
                for fn in self._listeners:
                    fn(ev)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    # ------------------------------------------------------------------
    def audit(self, budget_pages: Optional[int] = None,
              budget_grid=None) -> AuditReport:
        """Replay the observed trace against the exact offline reference.

        `budget_grid` (uniform sizes only): exact OPT-dollars for every
        budget in the grid from ONE warm-started parametric SSP run
        (`exact_opt_uniform_sweep`, DESIGN.md §5.2), reported in
        `opt_by_budget`; the bracket itself still refers to this cache's
        own budget. Observed dollars come from this cache's consumer meter
        — traffic other consumers billed on the shared store is excluded.
        """
        keys = self._trace_keys
        uniq = {k: i for i, k in enumerate(dict.fromkeys(keys))}
        ids = np.array([uniq[k] for k in keys], np.int32)
        sizes = np.zeros(len(uniq))
        for k, i in uniq.items():
            sizes[i] = self.store.size_of(k)
        costs = self.store.price.miss_cost(sizes)
        tr = Trace(ids=ids, sizes=sizes, name="egress_audit")
        uniform = len(set(sizes.tolist())) == 1
        opt_by_budget = None
        if uniform:
            B = budget_pages or max(1, int(self.capacity // sizes[0]))
            if budget_grid is not None:
                grid = np.unique(np.append(np.asarray(budget_grid, np.int64),
                                           B))
                sweep = exact_opt_uniform_sweep(ids, costs, grid)
                opt_by_budget = {int(b): float(d)
                                 for b, d in zip(sweep.budgets, sweep.dollars)}
                lower = upper = opt_by_budget[int(B)]
            else:
                o = exact_opt_uniform(ids, costs, B)
                lower = upper = o.dollars
        else:
            r = cost_foo(tr, costs, self.capacity)
            lower, upper = r.lower, r.upper
        # this cache's own bill — NOT the store-wide meter
        observed = float(self.meter.dollars)
        return AuditReport(
            policy=self.policy, observed_dollars=observed,
            opt_dollars_lower=lower, opt_dollars_upper=upper,
            dollar_regret=regret(observed, lower),
            heterogeneity=heterogeneity(ids, costs),
            crossover_bytes=self.store.price.crossover_bytes,
            mean_object_bytes=float(sizes[ids].mean()),
            requests=len(keys), hit_rate=self.hit_rate,
            opt_by_budget=opt_by_budget)
