"""Billing-faithful cloud object store simulator.

Every GET is billed `f + s_i * e` per the paper's eq. (1). The framework's
data pipeline, checkpoint restore path, and serving prefix cache all fetch
through this interface, so training/serving runs produce real billing
traces that the offline reference (core/) can audit.

Billing is attributed twice: once on the store-wide `meter`, and once on a
per-consumer meter (`meter_for(name)`) when the GET names its consumer —
so a cache's audit can score exactly the dollars *it* caused, not traffic
from other consumers sharing the store (DESIGN.md §8). Dollars accrue at
the price in effect when each GET happens, so `set_price` (a mid-stream
cloud repricing) never rewrites history.

Observability (DESIGN.md §9): an attached tracer (duck-typed — this layer
never imports `repro.obs`) gets one `store.get` span per billed GET,
carrying the exact dollars the meter accrued for it, the byte count, and
the size-vs-s* regime tag; summing span dollars per consumer reproduces
that consumer's meter total.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional

from repro.core.pricing import PRICE_VECTORS, PriceVector

__all__ = ["BillingMeter", "ObjectStore"]


@dataclasses.dataclass
class BillingMeter:
    price: PriceVector
    gets: int = 0
    puts: int = 0
    bytes_egressed: float = 0.0
    dollars: float = 0.0  # accrued at the price in effect at each GET

    def record_get(self, nbytes: float):
        self.gets += 1
        self.bytes_egressed += nbytes
        self.dollars += self.price.miss_cost_scalar(nbytes)

    def snapshot(self) -> dict:
        return dict(gets=self.gets, puts=self.puts,
                    bytes_egressed=self.bytes_egressed, dollars=self.dollars,
                    price=self.price.name)


class ObjectStore:
    """In-memory stand-in for S3/GCS/Azure blob, with per-GET billing.

    Objects may be stored eagerly (`put`) or lazily via a generator
    (`register_lazy`) so multi-GB synthetic datasets don't occupy RAM.
    """

    def __init__(self, price: PriceVector | str = "s3_internet",
                 tracer=None):
        if isinstance(price, str):
            price = PRICE_VECTORS[price]
        self.meter = BillingMeter(price)
        self.tracer = tracer    # duck-typed: .span(name, cat=..., **attrs)
        self._consumer_meters: dict[str, BillingMeter] = {}
        self._data: dict[str, bytes] = {}
        self._lazy: dict[str, tuple[int, Callable[[], bytes]]] = {}
        self._lock = threading.Lock()

    def set_tracer(self, tracer) -> None:
        """Attach/detach the span tracer (None or falsy disables)."""
        self.tracer = tracer

    # ---- pricing ----------------------------------------------------------
    @property
    def price(self) -> PriceVector:
        return self.meter.price

    def set_price(self, price: PriceVector | str) -> None:
        """Swap the billing vector mid-stream (cloud repricing). Already-
        accrued dollars are untouched; future GETs bill at the new rates."""
        if isinstance(price, str):
            price = PRICE_VECTORS[price]
        with self._lock:
            self.meter.price = price
            for m in self._consumer_meters.values():
                m.price = price

    # ---- per-consumer attribution -----------------------------------------
    def meter_for(self, consumer: str) -> BillingMeter:
        """The meter that bills only GETs naming `consumer`."""
        with self._lock:
            m = self._consumer_meters.get(consumer)
            if m is None:
                m = self._consumer_meters[consumer] = BillingMeter(self.meter.price)
            return m

    def consumer_snapshot(self) -> dict:
        """Per-consumer billing breakdown (dollars sum to meter.dollars when
        every GET names a consumer)."""
        return {name: m.snapshot() for name, m in self._consumer_meters.items()}

    # ---- producer side -----------------------------------------------------
    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._data[key] = data
            self.meter.puts += 1

    def register_lazy(self, key: str, nbytes: int,
                      producer: Callable[[], bytes]) -> None:
        with self._lock:
            self._lazy[key] = (nbytes, producer)

    # ---- consumer side (billed) ---------------------------------------------
    def get(self, key: str, consumer: Optional[str] = None) -> bytes:
        t = self.tracer
        if not t:
            return self._get_billed(key, consumer)
        with t.span("store.get", cat="store", key=key,
                    consumer=consumer or "") as sp:
            data = self._get_billed(key, consumer)
            nbytes = len(data)
            price = self.meter.price
            # the exact float the meter accrued for this GET
            sp.set(bytes=nbytes,
                   dollars=price.miss_cost_scalar(nbytes),
                   regime=("fee_dominated"
                           if nbytes <= price.crossover_bytes
                           else "egress_dominated"))
            return data

    def _get_billed(self, key: str, consumer: Optional[str]) -> bytes:
        with self._lock:
            if key in self._data:
                data = self._data[key]
            elif key in self._lazy:
                data = self._lazy[key][1]()
            else:
                raise KeyError(key)
            self.meter.record_get(len(data))
            if consumer is not None:
                m = self._consumer_meters.get(consumer)
                if m is None:
                    m = self._consumer_meters[consumer] = \
                        BillingMeter(self.meter.price)
                m.record_get(len(data))
            return data

    def size_of(self, key: str) -> int:
        if key in self._data:
            return len(self._data[key])
        if key in self._lazy:
            return self._lazy[key][0]
        raise KeyError(key)

    def contains(self, key: str) -> bool:
        return key in self._data or key in self._lazy

    def keys(self):
        return list(self._data) + list(self._lazy)
