"""Train/serve step assembly + sharding of params, optimizer state, caches.

make_train_step / make_decode_step produce the pure functions the launcher
jits for real runs and the dry-run lowers for the roofline analysis.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.registry import ModelApi
from repro.parallel.sharding import ShardingRules, params_sharding
from .optim import Optimizer, OptimizerConfig, make_optimizer

__all__ = ["make_train_step", "make_decode_step", "make_prefill",
           "train_state_shardings", "opt_state_sharding"]


def make_train_step(model: ModelApi, opt: Optimizer, *,
                    microbatches: int = 1, remat: bool = True,
                    loss_override=None, accum_dtype=jnp.float32,
                    grad_shardings=None):
    """(params, opt_state, batch) -> (loss, new_params, new_opt_state).

    With microbatches > 1 the batch's leading dim is split and gradients
    accumulate (dtype `accum_dtype`; bf16 halves the accumulator for the
    1T-param cell) across a lax.scan — one compiled body regardless of the
    microbatch count. `grad_shardings` (the param NamedShardings) pins the
    accumulator to the FSDP layout — without it GSPMD replicates the f32
    accumulator across the TP axis (measured 72 GiB/device on nemo-12b).
    `loss_override(params, batch)` substitutes the model's loss (the
    scan-layers MoE path uses this).
    """
    def loss_of(params, batch):
        if loss_override is not None:
            return loss_override(params, batch)
        return model.loss_fn(params, batch, remat=remat)

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    if microbatches == 1:
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
            new_params, new_state = opt.update(pin(grads), opt_state, params)
            return loss, new_params, new_state
        return step

    def step(params, opt_state, batch):
        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(acc, b):
            loss, grads = jax.value_and_grad(loss_of)(params, b)
            acc_loss, acc_g = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(accum_dtype), acc_g, pin(grads))
            return (acc_loss + loss, pin(acc_g)), None

        zero_g = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                                  params))
        (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mb)
        grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.bfloat16),
                             gsum)
        new_params, new_state = opt.update(grads, opt_state, params)
        return loss_sum / microbatches, new_params, new_state
    return step


def make_decode_step(model: ModelApi):
    def step(params, token, caches, position):
        return model.decode_step(params, token, caches, position)
    return step


def make_prefill(model: ModelApi):
    def step(params, batch):
        return model.prefill(params, batch)
    return step


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------

def opt_state_sharding(rules: ShardingRules, opt: Optimizer,
                       abstract_params, axes_tree):
    """NamedShardings for the optimizer state (factored stats drop an axis)."""
    mesh = rules.mesh
    name = opt.cfg.name
    if name == "adamw":
        per_param = jax.tree.map(
            lambda p, ax: NamedSharding(mesh, rules.spec_for(p.shape, ax)),
            abstract_params, axes_tree)
        return {"m": per_param, "v": per_param,
                "count": NamedSharding(mesh, P())}
    if name == "adafactor":
        from .optim import _factored

        def leaf(p, ax):
            if _factored(opt.cfg, p.shape):
                return {"vr": NamedSharding(
                            mesh, rules.spec_for(p.shape[:-1], ax[:-1])),
                        "vc": NamedSharding(
                            mesh, rules.spec_for(p.shape[:-2] + p.shape[-1:],
                                                 ax[:-2] + ax[-1:]))}
            return {"v": NamedSharding(mesh, rules.spec_for(p.shape, ax))}

        stats = jax.tree.map(leaf, abstract_params, axes_tree)
        return {"stats": stats, "count": NamedSharding(mesh, P())}
    if name == "sgd":
        return {"count": NamedSharding(mesh, P())}
    raise ValueError(name)


def train_state_shardings(rules: ShardingRules, model: ModelApi,
                          opt: Optimizer):
    """(param_shardings, opt_state_shardings, abstract_params,
    abstract_opt_state)."""
    ap = model.abstract()
    ax = model.axes()
    ps = params_sharding(rules, ap, ax)
    abstract_opt = jax.eval_shape(opt.init, ap)
    os = opt_state_sharding(rules, opt, ap, ax)
    return ps, os, ap, abstract_opt