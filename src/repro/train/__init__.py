# Training layer: step assembly, optimizer, sharded data, checkpointing,
# and the fault-tolerant driver loop.
from .checkpoint import latest_step, load_checkpoint, save_checkpoint
from .data import DataPipeline, ShardedTokenDataset
from .driver import DriverConfig, FailureInjector, TrainDriver
from .optim import Optimizer, OptimizerConfig, make_optimizer
from .trainer import (make_decode_step, make_prefill, make_train_step,
                      opt_state_sharding, train_state_shardings)

__all__ = ["make_train_step", "make_decode_step", "make_prefill",
           "train_state_shardings", "opt_state_sharding",
           "OptimizerConfig", "Optimizer", "make_optimizer",
           "ShardedTokenDataset", "DataPipeline",
           "save_checkpoint", "load_checkpoint", "latest_step",
           "DriverConfig", "TrainDriver", "FailureInjector"]
