"""Deterministic token data pipeline with egress-cached shard fetch.

Shards are synthetic token arrays registered lazily in the ObjectStore
(regenerable from their key — no RAM cost) and fetched through an
EgressCache, so every training run produces a billed access trace the
paper's offline reference can audit (examples/train_100m.py does exactly
that). Pipeline state (shard cursor, step) is part of the checkpoint, so
restarts resume bit-identically.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore

__all__ = ["ShardedTokenDataset", "DataPipeline"]


def _shard_tokens(key: str, shard_tokens: int, vocab: int) -> np.ndarray:
    seed = int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "little")
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=shard_tokens, dtype=np.int32)


@dataclasses.dataclass
class ShardedTokenDataset:
    store: ObjectStore
    num_shards: int
    shard_tokens: int
    vocab: int
    prefix: str = "data/shard"

    def register(self):
        for i in range(self.num_shards):
            key = f"{self.prefix}-{i:05d}.npy"
            nbytes = self.shard_tokens * 4
            self.store.register_lazy(
                key, nbytes,
                lambda k=key: _shard_tokens(k, self.shard_tokens,
                                            self.vocab).tobytes())
        return self

    def shard_key(self, i: int) -> str:
        return f"{self.prefix}-{i % self.num_shards:05d}.npy"


class DataPipeline:
    """Batch iterator reading shards through the egress cache."""

    def __init__(self, dataset: ShardedTokenDataset, cache: EgressCache,
                 batch_size: int, seq_len: int):
        self.ds = dataset
        self.cache = cache
        self.batch = batch_size
        self.seq = seq_len
        self.cursor = 0        # global token cursor (checkpointed)

    # ---- checkpointable state ------------------------------------------
    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict):
        self.cursor = int(state["cursor"])

    # ---- iteration --------------------------------------------------------
    def next_batch(self) -> dict:
        need = self.batch * self.seq
        out = np.empty(need, np.int32)
        got = 0
        while got < need:
            shard_i = self.cursor // self.ds.shard_tokens
            off = self.cursor % self.ds.shard_tokens
            raw = self.cache.get(self.ds.shard_key(shard_i))
            arr = np.frombuffer(raw, np.int32)
            take = min(need - got, len(arr) - off)
            out[got:got + take] = arr[off:off + take]
            got += take
            self.cursor += take
        tok = out.reshape(self.batch, self.seq)
        return {"tokens": tok, "labels": tok}