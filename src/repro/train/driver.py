"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler mitigation.

The driver owns the loop: data pipeline -> jit'd train_step -> periodic
atomic checkpoint. Failures (real or injected) abort the process state;
`TrainDriver.resume()` restores the latest complete checkpoint — params,
optimizer state, data cursor and step — and continues bit-identically
(tests/test_fault_tolerance.py proves equality against an uninterrupted
run).

Straggler mitigation (single-process simulation of the fleet policy): the
driver tracks a robust step-time estimate; steps slower than
`straggler_factor` x median are logged and counted, and the configured
callback fires (on a real fleet: re-shard away from / hot-swap the slow
host; here: the hook + accounting, unit-tested).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from .checkpoint import latest_step, load_checkpoint, save_checkpoint

__all__ = ["DriverConfig", "TrainDriver", "FailureInjector"]


@dataclasses.dataclass
class DriverConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    max_steps: int = 200
    straggler_factor: float = 3.0
    keep_checkpoints: int = 3


class FailureInjector:
    """Deterministic failure schedule for tests: raises at given steps."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class TrainDriver:
    def __init__(self, cfg: DriverConfig, train_step: Callable,
                 params, opt_state, pipeline,
                 failure: Optional[FailureInjector] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.failure = failure
        self.on_straggler = on_straggler
        self.step = 0
        self.losses: list[float] = []
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []

    # ------------------------------------------------------------------
    def _checkpoint(self):
        save_checkpoint(self.cfg.checkpoint_dir, self.step,
                        {"params": self.params, "opt": self.opt_state},
                        extra={"pipeline": self.pipeline.state(),
                               "losses": self.losses[-5:]})
        # retention
        import pathlib, shutil
        d = pathlib.Path(self.cfg.checkpoint_dir)
        steps = sorted(int(p.name[5:]) for p in d.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for s in steps[:-self.cfg.keep_checkpoints]:
            shutil.rmtree(d / f"step_{s:08d}")

    def resume(self) -> bool:
        """Restore the latest complete checkpoint. True if one was found."""
        s = latest_step(self.cfg.checkpoint_dir)
        if s is None:
            return False
        tree, extra = load_checkpoint(
            self.cfg.checkpoint_dir, s,
            {"params": self.params, "opt": self.opt_state})
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.pipeline.restore(extra["pipeline"])
        self.step = s
        return True

    # ------------------------------------------------------------------
    def run(self) -> dict:
        import jax.numpy as jnp
        while self.step < self.cfg.max_steps:
            if self.failure is not None:
                self.failure.maybe_fail(self.step)
            batch = self.pipeline.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            # perf_counter: monotonic — wall-clock (NTP) skew would corrupt
            # the straggler detector's step-time medians
            t0 = time.perf_counter()
            loss, self.params, self.opt_state = self.train_step(
                self.params, self.opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-20:]))
            if len(self.step_times) > 5 and dt > self.cfg.straggler_factor * med:
                self.straggler_steps.append(self.step)
                if self.on_straggler:
                    self.on_straggler(self.step, dt / med)
            self.losses.append(loss)
            self.step += 1
            if self.step % self.cfg.checkpoint_every == 0:
                self._checkpoint()
        self._checkpoint()
        return {"final_loss": self.losses[-1] if self.losses else None,
                "steps": self.step, "stragglers": self.straggler_steps}