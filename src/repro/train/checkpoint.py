"""Atomic, sharded, resumable checkpoints.

Layout:  <dir>/step_<N>.tmp/...   (write)
         <dir>/step_<N>/          (atomic rename on completion)
           manifest.json           {step, leaf paths, shapes, dtypes, extra}
           arr_<k>.npy             one file per pytree leaf

Restore is resharding-tolerant: leaves are loaded host-side and device_put
against whatever shardings the *new* mesh prescribes, so a job can restart
on a different ("pod","data") extent (elastic scaling).
"""
from __future__ import annotations

import json
import pathlib
import shutil
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def save_checkpoint(directory, step: int, tree: Any,
                    extra: Optional[dict] = None) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step:08d}.tmp"
    final = d / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        dtype = str(arr.dtype)
        if dtype == "bfloat16":   # numpy can't round-trip ml_dtypes natively
            arr = arr.view(np.uint16)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype,
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)   # atomic commit
    return final


def latest_step(directory) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and not p.name.endswith(".tmp") \
                and (p / "manifest.json").exists():
            steps.append(int(p.name[5:]))
    return max(steps) if steps else None


def load_checkpoint(directory, step: int, like: Any,
                    shardings: Any = None) -> tuple[Any, dict]:
    """Restore a pytree saved by save_checkpoint.

    `like` provides the pytree structure; `shardings` (optional, same
    structure) re-shards each leaf onto the current mesh (elastic restart).
    """
    d = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like, treedef = _flatten_with_paths(like)
    assert len(flat_like) == len(manifest["leaves"]), \
        f"leaf count mismatch: {len(flat_like)} vs {len(manifest['leaves'])}"
    leaves = []
    for (path, leaf), rec in zip(flat_like, manifest["leaves"]):
        arr = np.load(d / rec["file"])
        if rec["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", arr.shape))
        assert tuple(arr.shape) == want, (rec["path"], arr.shape, want)
        leaves.append(arr)
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(
            jax.tree.map(lambda s: s, shardings))
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.numpy.asarray(a) for a in leaves]
    tree = treedef.unflatten(leaves)
    return tree, manifest["extra"]