"""Optimizers: AdamW (dtype-configurable moments) and Adafactor.

Optimizer-state memory is the binding constraint for the 1T-param cell
(kimi-k2 on 256 x 16 GB): f32 Adam moments need 23.4 GB/chip — Adafactor's
factored second moment fits (DESIGN.md §5). Every state leaf inherits the
parameter's sharding (factored stats drop the corresponding axis).

API: opt = make_optimizer(cfg); state = opt.init(params);
     new_params, new_state = opt.update(grads, state, params)
Gradient math is f32 regardless of storage dtype; the cross-device gradient
reduction happens in bf16 (compression) before the f32 update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "Optimizer", "make_optimizer"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"           # adamw | adafactor | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    moment_dtype: Any = jnp.float32   # bf16 halves Adam memory
    # adafactor
    factored_min_dim: int = 128
    clip_threshold: float = 1.0


@dataclasses.dataclass(frozen=True)
class Optimizer:
    cfg: OptimizerConfig
    init: Callable
    update: Callable
    state_axes: Callable   # param logical axes -> state logical axes pytree


def _adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        b1c = 1 - cfg.b1 ** c.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** c.astype(jnp.float32)

        def leaf(g, m, v, p):
            g = g.astype(jnp.float32)
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            upd = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - cfg.lr * upd
            return (newp.astype(p.dtype), m32.astype(cfg.moment_dtype),
                    v32.astype(cfg.moment_dtype))

        out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        newm = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        newv = jax.tree.map(lambda t: t[2], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        return newp, {"m": newm, "v": newv, "count": c}

    def state_axes(param_axes):
        return {"m": param_axes, "v": param_axes, "count": None}

    return Optimizer(cfg, init, update, state_axes)


def _factored(cfg, shape) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.factored_min_dim
            and shape[-2] >= cfg.factored_min_dim)


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    """Adafactor without momentum (beta1=None), factored second moment."""
    def init(params):
        def leaf(p):
            if _factored(cfg, p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(leaf, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        beta2 = 1.0 - c.astype(jnp.float32) ** -0.8

        def leaf(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + 1e-30
            if "vr" in st:
                vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(-2)
                denom = vr.mean(-1, keepdims=True)
                vhat = (vr[..., None] * vc[..., None, :]
                        / jnp.maximum(denom[..., None], 1e-30))
                upd = g / jnp.sqrt(jnp.maximum(vhat, 1e-30))
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                upd = g / jnp.sqrt(jnp.maximum(v, 1e-30))
                new_st = {"v": v}
            # relative RMS clipping (Adafactor eq. 6)
            rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / cfg.clip_threshold)
            newp = (p.astype(jnp.float32)
                    - cfg.lr * upd - cfg.lr * cfg.weight_decay
                    * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_st

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["stats"])
        outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        newp = tdef.unflatten([o[0] for o in outs])
        news = tdef.unflatten([o[1] for o in outs])
        return newp, {"stats": news, "count": c}

    def state_axes(param_axes):
        def leaf_axes(axes, p):
            if _factored(cfg, p.shape):
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}
        # needs params for shapes; resolved in trainer where both exist
        return leaf_axes

    return Optimizer(cfg, init, update, state_axes)


def _sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        newp = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - cfg.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return newp, {"count": state["count"] + 1}

    return Optimizer(cfg, init, update, lambda axes: {"count": None})


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return {"adamw": _adamw, "adafactor": _adafactor, "sgd": _sgd}[cfg.name](cfg)