"""In-process gossip fabric with injectable faults (DESIGN.md §10).

`SimNetwork` is a deterministic message switch between named participants:
`send(src, dst, frame)` enqueues an opaque wire frame, `deliver()` advances
one round and returns everything due. Faults are injected per frame from a
seeded RNG, so every failure scenario replays exactly:

  * drop       — the frame silently disappears (probability per frame)
  * duplicate  — the frame is enqueued twice
  * reorder    — the frame's delivery order within its round is randomized
                 instead of FIFO
  * delay      — delivery is postponed up to `max_delay` extra rounds

`GossipState` is each participant's merged view of the fleet's window
evidence: a map (host, window_id) -> highest-seq `WindowDelta`. Merging is
idempotent and commutative — duplicates and reordering cannot change the
converged state, and drops heal because every round re-broadcasts full
state (anti-entropy). Convergence is therefore "all participants share the
same digest", which the fleet's `flush()` drives to a fixpoint and the
fault-injection tests assert under drop+duplicate+reorder together.

Fleet-wide per-policy dollar totals are `fsum`s over the merged deltas, so
every converged participant computes the identical total.
"""
from __future__ import annotations

import heapq
import math
import random

from .wire import WindowDelta

__all__ = ["SimNetwork", "GossipState"]


class SimNetwork:
    """Deterministic fault-injecting switch for wire frames."""

    def __init__(self, seed: int = 0, drop: float = 0.0,
                 duplicate: float = 0.0, reorder: float = 0.0,
                 max_delay: int = 0):
        assert 0.0 <= drop < 1.0 and 0.0 <= duplicate <= 1.0
        assert 0.0 <= reorder <= 1.0 and max_delay >= 0
        self.rng = random.Random(seed)
        self.drop = drop
        self.duplicate = duplicate
        self.reorder = reorder
        self.max_delay = int(max_delay)
        self.round = 0
        self._heap: list[tuple] = []   # (due_round, order_key, n, src, dst, frame)
        self._n = 0
        self.sent = 0
        self.delivered = 0
        self.dropped = 0
        self.duplicated = 0
        self.reordered = 0
        self.delayed = 0

    def send(self, src: str, dst: str, frame: bytes) -> None:
        self.sent += 1
        if self.drop and self.rng.random() < self.drop:
            self.dropped += 1
            return
        copies = 1
        if self.duplicate and self.rng.random() < self.duplicate:
            self.duplicated += 1
            copies = 2
        for _ in range(copies):
            self._enqueue(src, dst, frame)

    def _enqueue(self, src: str, dst: str, frame: bytes) -> None:
        due = self.round + 1
        if self.max_delay:
            extra = self.rng.randint(0, self.max_delay)
            if extra:
                self.delayed += 1
                due += extra
        if self.reorder and self.rng.random() < self.reorder:
            self.reordered += 1
            order_key = self.rng.random()      # jumps the FIFO queue
        else:
            order_key = 1.0 + self._n          # FIFO within the round
        heapq.heappush(self._heap, (due, order_key, self._n, src, dst, frame))
        self._n += 1

    def deliver(self) -> list[tuple[str, str, bytes]]:
        """Advance one round; returns due frames as (dst, src, frame)."""
        self.round += 1
        out = []
        while self._heap and self._heap[0][0] <= self.round:
            _, _, _, src, dst, frame = heapq.heappop(self._heap)
            out.append((dst, src, frame))
            self.delivered += 1
        return out

    @property
    def in_flight(self) -> int:
        return len(self._heap)

    def snapshot(self) -> dict:
        return dict(round=self.round, sent=self.sent,
                    delivered=self.delivered, dropped=self.dropped,
                    duplicated=self.duplicated, reordered=self.reordered,
                    delayed=self.delayed, in_flight=self.in_flight,
                    faults=dict(drop=self.drop, duplicate=self.duplicate,
                                reorder=self.reorder,
                                max_delay=self.max_delay))


class GossipState:
    """Merged window evidence: (host, window_id) -> highest-seq delta."""

    def __init__(self):
        self.deltas: dict[tuple[str, int], WindowDelta] = {}
        self.merges = 0          # merges that changed the state
        self.stale = 0           # duplicates / lower-seq arrivals ignored

    def merge(self, delta: WindowDelta) -> bool:
        """Idempotent, commutative merge; True iff the state changed."""
        k = (delta.host, delta.window_id)
        cur = self.deltas.get(k)
        if cur is not None and cur.seq >= delta.seq:
            self.stale += 1
            return False
        self.deltas[k] = delta
        self.merges += 1
        return True

    def __len__(self) -> int:
        return len(self.deltas)

    def window_ids(self) -> list[int]:
        return sorted({wid for _, wid in self.deltas})

    def window_hosts(self, window_id: int) -> dict[str, WindowDelta]:
        """All hosts' deltas for one window (quorum checks read this)."""
        return {h: d for (h, w), d in self.deltas.items() if w == window_id}

    def fleet_window_dollars(self, window_id: int) -> dict[str, float]:
        """Per-policy fleet totals over the hosts seen for this window."""
        return self._totals(self.window_hosts(window_id).values())

    def fleet_totals(self) -> dict[str, float]:
        """Per-policy fleet totals over every merged delta."""
        return self._totals(self.deltas.values())

    @staticmethod
    def _totals(deltas) -> dict[str, float]:
        per_policy: dict[str, list[float]] = {}
        for d in deltas:
            for policy, v in d.dollars.items():
                per_policy.setdefault(policy, []).append(v)
        # fsum + sorted host/window iteration independence: exact rounding
        # of the true sum, so converged participants agree bit-for-bit
        return {p: math.fsum(vs) for p, vs in sorted(per_policy.items())}

    def digest(self) -> tuple:
        """Order-independent identity of the state; equal digests across
        participants == converged."""
        return tuple(sorted((h, w, d.seq)
                            for (h, w), d in self.deltas.items()))

    def snapshot(self) -> dict:
        return dict(deltas=len(self.deltas), merges=self.merges,
                    stale=self.stale, windows=len(self.window_ids()))
