"""One edge host of the fleet: live cache + shadow panel + event-time windows.

A `FleetNode` wraps the billing path of a single simulated edge host —
an `EgressCache` over the shared origin `ObjectStore`, billed through the
host's own consumer meter — together with the governance evidence it
contributes to the fleet:

  * a metadata-only `ShadowPanel` replaying every local access against all
    candidate policies ($0 of extra egress, exactly as in DESIGN.md §8);
  * clock-skew-tolerant *event-time* windowing: accesses carry a global
    event time (the fleet stamps the trace index), windows are tumbling
    spans `[k*span, (k+1)*span)` aligned across hosts, and a window closes
    only once the host's `Watermark` (shared with `WindowedAuditor`)
    passes its end. Bounded skew is asserted by the watermark, which
    guarantees a late event's window is *still open* when it arrives —
    late events therefore fold into the open window instead of reopening
    a closed one (`late_folded` counts the defensive fallback path);
  * a wire log of every `AccessEvent` (`repro.fleet.wire` frames), so the
    host's bill can be re-derived off-host: `replayed_dollars()` decodes
    the log and re-accrues miss costs in arrival order with the meter's
    own arithmetic — bit-equal to `cache.meter.dollars`.

Closed windows become `WindowDelta` messages in `outbox`, merged into the
node's own `GossipState` and broadcast by the fleet's gossip rounds.
Hosts emit a *contiguous* window sequence (empty windows included), so a
quorum of deltas per window is reachable even when a partition goes quiet.
"""
from __future__ import annotations

import math

from repro.egress.cache import ONLINE_POLICIES, AccessEvent, EgressCache
from repro.egress.store import ObjectStore
from repro.online.shadow import ShadowPanel
from repro.online.window import Watermark

from .gossip import GossipState
from .wire import WindowDelta, decode_access_event, encode_access_event

__all__ = ["FleetNode"]


class FleetNode:
    def __init__(self, host: str, store: ObjectStore, capacity_bytes: float,
                 policy: str = "lru",
                 policies: tuple[str, ...] = ONLINE_POLICIES,
                 window_span: float = 512.0, max_skew: float = 64.0,
                 events=None, metrics=None, keep_wire_log: bool = True):
        assert window_span > 0, window_span
        self.host = host
        self.cache = EgressCache(store, capacity_bytes, policy,
                                 consumer=host, metrics=metrics,
                                 events=events)
        self.policies = tuple(policies)
        self.panel = ShadowPanel(capacity_bytes, self.policies)
        self.window_span = float(window_span)
        self.watermark = Watermark(max_skew)
        self.state = GossipState()
        self.outbox: list[WindowDelta] = []
        self.keep_wire_log = keep_wire_log
        self.wire_log: list[bytes] = []
        self.late_folded = 0          # defensive fold-into-open-window path
        self._open: dict[int, dict] = {}    # window_id -> accumulator
        self._last_closed = -1
        self._seq = 0
        self.cache.add_listener(self._on_event)

    # ------------------------------------------------------------------
    def access(self, key: str, event_time: float) -> bytes:
        """Serve one request at the given event time (the global trace
        position); closes any windows the watermark has passed."""
        data = self.cache.get(key, event_time=float(event_time))
        self._close_ripe()
        return data

    def _on_event(self, ev: AccessEvent) -> None:
        t = ev.event_time
        self.watermark.advance(t)             # asserts bounded skew
        if self.keep_wire_log:
            self.wire_log.append(encode_access_event(ev))
        shadows = self.panel.shadows
        before = [sh.dollars for sh in shadows.values()]
        self.panel.on_event(ev)
        wid = int(t // self.window_span)
        if wid <= self._last_closed:
            # bounded skew guarantees a late event's own window is still
            # open (it closes only at watermark = end + skew); this branch
            # is the defensive boundary case (lateness == max_skew exactly)
            self.late_folded += 1
            wid = min(self._open, default=self._last_closed + 1)
        acc = self._open.get(wid)
        if acc is None:
            acc = self._open[wid] = dict(events=0, dollars=dict.fromkeys(
                self.policies, 0.0))
        acc["events"] += 1
        dollars = acc["dollars"]
        for policy, b in zip(shadows, before):
            dollars[policy] += shadows[policy].dollars - b

    # ------------------------------------------------------------------
    def _close_ripe(self) -> None:
        wm = self.watermark.value
        if not math.isfinite(wm):
            return
        # window w is closeable iff (w+1)*span <= watermark
        w_max = int(wm // self.window_span) - 1
        for w in range(self._last_closed + 1, w_max + 1):
            self._emit(w)

    def _emit(self, wid: int) -> None:
        acc = self._open.pop(wid, None) or dict(
            events=0, dollars=dict.fromkeys(self.policies, 0.0))
        self._seq += 1
        delta = WindowDelta(self.host, wid, self._seq, self.watermark.value,
                            acc["events"], dict(acc["dollars"]))
        self._last_closed = max(self._last_closed, wid)
        self.outbox.append(delta)
        self.state.merge(delta)

    def flush(self) -> None:
        """End-of-stream: close every window seen, watermark regardless
        (keeps the emitted sequence contiguous through the last event)."""
        if self._open:
            for w in range(self._last_closed + 1, max(self._open) + 1):
                self._emit(w)

    # ------------------------------------------------------------------
    def replayed_dollars(self) -> float:
        """Re-accrue this host's bill from the decoded wire log: naive sum
        of miss costs in arrival order — the same floats in the same order
        with the same IEEE addition as `BillingMeter.record_get`, hence
        bit-equal to `cache.meter.dollars`."""
        total = 0.0
        for raw in self.wire_log:
            ev = decode_access_event(raw)
            if not ev.hit:
                total += ev.miss_cost
        return total

    def audit(self):
        """This host's exact offline audit (its own partition's trace);
        None for a host that saw no traffic — an empty trace has no OPT
        to bracket, and its meter holds exactly $0."""
        if self.cache.hits + self.cache.misses == 0:
            return None
        return self.cache.audit()

    def snapshot(self) -> dict:
        return dict(
            host=self.host, policy=self.cache.policy,
            dollars=self.cache.meter.dollars,
            hits=self.cache.hits, misses=self.cache.misses,
            hit_rate=self.cache.hit_rate, used=self.cache.used,
            windows_closed=self._seq, late_folded=self.late_folded,
            late_events=self.watermark.late,
            watermark=self.watermark.value,
            shadow=self.panel.snapshot())
