# Fleet governance over many edge hosts (DESIGN.md §10): one origin store,
# N FleetNodes (live cache + shadow panel + event-time watermark windows),
# a fault-injectable gossip fabric exchanging WindowDelta evidence, and a
# coordinator applying quorum dollar-policy swaps fleet-wide.
#   wire        — versioned binary/JSON framing; dollars round-trip bit-equal
#   node        — per-host cache + shadow windows + wire log
#   gossip      — SimNetwork (drop/duplicate/reorder/delay) + GossipState
#   coordinator — quorum votes, centralized tiebreak, the Fleet facade
# Layering: fleet sits above egress/online and publishes to obs duck-typed
# (events/metrics arrive as plain objects; repro.obs is never imported).
from .wire import (WIRE_VERSION, WindowDelta, WireError,
                   access_event_from_json, access_event_to_json, decode,
                   decode_access_event, decode_window_delta,
                   encode_access_event, encode_window_delta)
from .gossip import GossipState, SimNetwork
from .node import FleetNode
from .coordinator import Fleet, FleetCoordinator, FleetSwap, hash_partition

__all__ = [
    "WIRE_VERSION", "WireError", "WindowDelta",
    "encode_access_event", "decode_access_event",
    "encode_window_delta", "decode_window_delta", "decode",
    "access_event_to_json", "access_event_from_json",
    "SimNetwork", "GossipState", "FleetNode",
    "Fleet", "FleetCoordinator", "FleetSwap", "hash_partition",
]
