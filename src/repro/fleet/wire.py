"""Versioned wire format for fleet-governance messages (DESIGN.md §10).

Two message kinds cross host boundaries:

  * `AccessEvent` — one cache access, exactly as the governance listeners
    see it locally. Hosts append every event to a wire log; replaying a
    decoded log re-accrues the host's bill *bit-for-bit* (the `miss_cost`
    float round-trips exactly — IEEE-754 doubles are framed verbatim, no
    decimal detour), which is what makes cross-host audits reconcilable
    with the per-node `BillingMeter`s.
  * `WindowDelta` — one host's closed event-time window: per-policy shadow
    dollars, the event count, and the host watermark at close. This is the
    gossip payload; fleet-wide per-policy totals are sums of deltas.

Framing is deliberately boring: 2-byte magic, u8 version, u8 kind, a
fixed-layout payload (strings are u16-length-prefixed UTF-8, floats are
little-endian f64), and a CRC-32 trailer over everything before it. Any
magic/version/kind/checksum/layout violation raises `WireError` — a
corrupt frame is rejected, never half-parsed (property-tested in
tests/test_fleet_property.py). A JSON codec for `AccessEvent` is provided
for logs meant to be read by humans or non-Python consumers; it carries
`miss_cost`/`event_time` both as plain floats (readable) and as C99 hex
floats (`float.hex()`, bit-exact), and decoding prefers the hex form.
"""
from __future__ import annotations

import binascii
import dataclasses
import json
import struct

from repro.egress.cache import ONLINE_POLICIES, AccessEvent

__all__ = [
    "WIRE_VERSION", "WireError", "WindowDelta",
    "encode_access_event", "decode_access_event",
    "encode_window_delta", "decode_window_delta", "decode",
    "access_event_to_json", "access_event_from_json",
]

WIRE_VERSION = 1
_MAGIC = b"FG"                       # "fleet governance"
KIND_ACCESS_EVENT = 0
KIND_WINDOW_DELTA = 1
_KINDS = (KIND_ACCESS_EVENT, KIND_WINDOW_DELTA)


class WireError(ValueError):
    """Raised for any malformed frame: bad magic, unsupported version,
    unknown kind, checksum mismatch, or a payload layout violation."""


@dataclasses.dataclass(frozen=True)
class WindowDelta:
    """One host's closed event-time window of shadow-dollar evidence.

    `seq` is the host's monotone emission counter: gossip merges keep the
    highest seq per (host, window_id), so duplicated or reordered delivery
    can never regress a peer's view (see gossip.GossipState).
    """
    host: str
    window_id: int
    seq: int
    watermark: float          # host watermark when the window closed
    events: int               # accesses folded into this window
    dollars: dict             # policy -> windowed counterfactual dollars


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def _frame(kind: int, payload: bytes) -> bytes:
    body = _MAGIC + struct.pack("<BB", WIRE_VERSION, kind) + payload
    return body + struct.pack("<I", binascii.crc32(body))


def _unframe(buf: bytes, expect_kind: int) -> bytes:
    if len(buf) < 8:
        raise WireError(f"frame truncated: {len(buf)} bytes")
    if buf[:2] != _MAGIC:
        raise WireError(f"bad magic {buf[:2]!r}")
    version, kind = struct.unpack_from("<BB", buf, 2)
    if version != WIRE_VERSION:
        raise WireError(f"unsupported wire version {version}")
    if kind not in _KINDS:
        raise WireError(f"unknown message kind {kind}")
    (crc,) = struct.unpack_from("<I", buf, len(buf) - 4)
    if binascii.crc32(buf[:-4]) != crc:
        raise WireError("checksum mismatch")
    if kind != expect_kind:
        raise WireError(f"expected kind {expect_kind}, got {kind}")
    return buf[4:-4]


def _peek_kind(buf: bytes) -> int:
    if len(buf) < 4 or buf[:2] != _MAGIC:
        raise WireError("bad or truncated frame header")
    return buf[3]


def _policy_index(policy: str) -> int:
    try:
        return ONLINE_POLICIES.index(policy)
    except ValueError:
        raise WireError(f"unknown policy {policy!r}") from None


# ---------------------------------------------------------------------------
# AccessEvent
# ---------------------------------------------------------------------------

_EV_FIXED = struct.Struct("<BBQQdd")   # policy, hit, nbytes, clock, mc, t


def encode_access_event(ev: AccessEvent) -> bytes:
    key = ev.key.encode("utf-8")
    if len(key) > 0xFFFF:
        raise WireError(f"key too long for wire format: {len(key)} bytes")
    payload = (struct.pack("<H", len(key)) + key
               + _EV_FIXED.pack(_policy_index(ev.policy), 1 if ev.hit else 0,
                                ev.nbytes, ev.clock, ev.miss_cost,
                                ev.event_time))
    return _frame(KIND_ACCESS_EVENT, payload)


def decode_access_event(buf: bytes) -> AccessEvent:
    p = _unframe(buf, KIND_ACCESS_EVENT)
    try:
        (klen,) = struct.unpack_from("<H", p, 0)
        key = p[2:2 + klen].decode("utf-8")
        if len(p) != 2 + klen + _EV_FIXED.size:
            raise WireError(f"payload length mismatch: {len(p)} bytes")
        pol, hit, nbytes, clock, mc, t = _EV_FIXED.unpack_from(p, 2 + klen)
    except (struct.error, UnicodeDecodeError) as e:
        raise WireError(f"malformed AccessEvent payload: {e}") from None
    if pol >= len(ONLINE_POLICIES) or hit > 1:
        raise WireError(f"field out of range: policy={pol} hit={hit}")
    return AccessEvent(key, nbytes, bool(hit), mc, ONLINE_POLICIES[pol],
                       clock, t)


def access_event_to_json(ev: AccessEvent) -> str:
    return json.dumps(dict(
        v=WIRE_VERSION, kind="access_event", key=ev.key, nbytes=ev.nbytes,
        hit=ev.hit, policy=ev.policy, clock=ev.clock,
        miss_cost=ev.miss_cost, miss_cost_hex=float(ev.miss_cost).hex(),
        event_time=ev.event_time,
        event_time_hex=float(ev.event_time).hex()), sort_keys=True)


def access_event_from_json(line: str) -> AccessEvent:
    try:
        d = json.loads(line)
    except json.JSONDecodeError as e:
        raise WireError(f"malformed JSON frame: {e}") from None
    if d.get("v") != WIRE_VERSION:
        raise WireError(f"unsupported wire version {d.get('v')}")
    if d.get("kind") != "access_event":
        raise WireError(f"unexpected kind {d.get('kind')!r}")
    if d.get("policy") not in ONLINE_POLICIES:
        raise WireError(f"unknown policy {d.get('policy')!r}")
    try:
        # the hex fields are authoritative (bit-exact); plain floats are
        # for human eyes and lossy-JSON consumers
        mc = float.fromhex(d["miss_cost_hex"]) if "miss_cost_hex" in d \
            else float(d["miss_cost"])
        t = float.fromhex(d["event_time_hex"]) if "event_time_hex" in d \
            else float(d["event_time"])
        return AccessEvent(str(d["key"]), int(d["nbytes"]), bool(d["hit"]),
                           mc, d["policy"], int(d["clock"]), t)
    except (KeyError, ValueError, TypeError) as e:
        raise WireError(f"malformed AccessEvent JSON: {e}") from None


# ---------------------------------------------------------------------------
# WindowDelta
# ---------------------------------------------------------------------------

_WD_FIXED = struct.Struct("<QQdIB")    # window_id, seq, watermark, events, n


def encode_window_delta(d: WindowDelta) -> bytes:
    host = d.host.encode("utf-8")
    if len(host) > 0xFFFF:
        raise WireError(f"host name too long: {len(host)} bytes")
    parts = [struct.pack("<H", len(host)), host,
             _WD_FIXED.pack(d.window_id, d.seq, d.watermark, d.events,
                            len(d.dollars))]
    for policy in sorted(d.dollars, key=_policy_index):
        parts.append(struct.pack("<Bd", _policy_index(policy),
                                 d.dollars[policy]))
    return _frame(KIND_WINDOW_DELTA, b"".join(parts))


def decode_window_delta(buf: bytes) -> WindowDelta:
    p = _unframe(buf, KIND_WINDOW_DELTA)
    try:
        (hlen,) = struct.unpack_from("<H", p, 0)
        host = p[2:2 + hlen].decode("utf-8")
        wid, seq, wm, events, n = _WD_FIXED.unpack_from(p, 2 + hlen)
        off = 2 + hlen + _WD_FIXED.size
        if len(p) != off + n * 9:
            raise WireError(f"payload length mismatch: {len(p)} bytes")
        dollars = {}
        for _ in range(n):
            pol, dv = struct.unpack_from("<Bd", p, off)
            off += 9
            if pol >= len(ONLINE_POLICIES):
                raise WireError(f"policy index out of range: {pol}")
            dollars[ONLINE_POLICIES[pol]] = dv
    except (struct.error, UnicodeDecodeError) as e:
        raise WireError(f"malformed WindowDelta payload: {e}") from None
    return WindowDelta(host, wid, seq, wm, events, dollars)


def decode(buf: bytes):
    """Decode either message kind (gossip receivers dispatch here)."""
    if _peek_kind(buf) == KIND_ACCESS_EVENT:
        return decode_access_event(buf)
    return decode_window_delta(buf)
