"""Fleet-wide dollar-policy swaps: quorum votes with a centralized tiebreak.

`FleetCoordinator` turns gossiped `WindowDelta`s into swap decisions. Each
host's *vote* for a window is a deterministic function of its own delta —
`DollarGovernor`'s hysteresis rule verbatim: leave the incumbent only if
the best policy's windowed shadow dollars undercut the incumbent's by the
relative `hysteresis` margin. Votes are weighted by the incumbent's
dollars on that host's partition (the dollars actually at stake there), so
a quiet edge cannot out-vote the host paying the bill. Because the vote is
derived from the delta itself, no separate ballot messages exist — gossip
convergence *is* vote delivery.

A window is decided once a quorum (default: majority of hosts) of deltas
is present, strictly in window order, exactly once (`decided` memoizes;
duplicated or re-delivered deltas can never re-apply a swap — the
fault-injection tests assert this). The decision rule:

  * a policy holding a strict majority of the vote weight wins ("quorum");
  * otherwise, in `mode="central"`, the coordinator breaks the tie from
    its own merged view — argmin of the fleet-aggregated window dollars,
    hysteresis against the incumbent ("tiebreak");
  * otherwise the incumbent stands.

Swaps apply atomically across the fleet (`EgressCache.set_policy` on every
node: contents preserved, $0 to swap) and publish through the duck-typed
obs surface — a `policy_swap` decision event plus `fleet.*` metrics.

`Fleet` is the facade: N `FleetNode`s over one shared origin store, a
`SimNetwork`, hash partitioning, gossip rounds, and the coordinator. Its
billing identity: `dollars()` is the fsum over per-node `BillingMeter`s
and reconciles bit-for-bit with the sum of per-node audits.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Callable, Optional

from repro.egress.cache import ONLINE_POLICIES
from repro.egress.store import ObjectStore

from .gossip import GossipState, SimNetwork
from .node import FleetNode
from .wire import WindowDelta, decode_window_delta, encode_window_delta

__all__ = ["FleetCoordinator", "FleetSwap", "Fleet", "hash_partition"]


def hash_partition(key: str, n_nodes: int) -> int:
    """Stable key -> host assignment (crc32: cheap, seed-free, portable)."""
    return zlib.crc32(key.encode("utf-8")) % n_nodes


@dataclasses.dataclass(frozen=True)
class FleetSwap:
    window_id: int
    old_policy: str
    new_policy: str
    mode: str                  # "quorum" | "tiebreak"
    votes: dict                # host -> [vote, weight]
    round: int                 # network round at application time


class FleetCoordinator:
    def __init__(self, n_hosts: int, policy: str = "lru",
                 policies: tuple[str, ...] = ONLINE_POLICIES,
                 hysteresis: float = 0.1, quorum: Optional[int] = None,
                 mode: str = "quorum", events=None, metrics=None):
        assert mode in ("quorum", "central"), mode
        assert hysteresis >= 0.0
        self.n_hosts = int(n_hosts)
        self.policy = policy               # fleet-wide incumbent
        self.policies = tuple(policies)
        self.hysteresis = float(hysteresis)
        self.quorum = (self.n_hosts // 2 + 1) if quorum is None else int(quorum)
        assert 1 <= self.quorum <= self.n_hosts, self.quorum
        self.mode = mode
        self.events = events               # duck-typed: .record(kind, ...)
        self.metrics = metrics             # duck-typed: .inc(name, value)
        self.state = GossipState()
        self.decided: dict[int, str] = {}  # window_id -> decided policy
        self.frontier = -1                 # highest contiguously decided wid
        self.swaps: list[FleetSwap] = []

    # ------------------------------------------------------------------
    def ingest(self, delta: WindowDelta) -> bool:
        return self.state.merge(delta)

    def vote_of(self, delta: WindowDelta) -> tuple[str, float]:
        """One host's (vote, weight) from its own window evidence —
        DollarGovernor's hysteresis rule, weight = incumbent dollars."""
        d = delta.dollars
        inc = self.policy
        weight = d.get(inc, 0.0)
        if not d:
            return inc, 0.0
        best = min(d, key=d.get)
        if best != inc and d[best] < (1.0 - self.hysteresis) * weight:
            return best, weight
        return inc, weight

    def poll(self, apply_fn: Optional[Callable[[str, "FleetSwap"], None]]
             = None, network_round: int = 0) -> list[FleetSwap]:
        """Decide every window with a quorum of deltas, oldest first.

        Windows decide strictly in order (a gap without quorum blocks the
        rest — votes depend on the incumbent at decision time), and each
        at most once: re-delivered evidence for a decided window is inert.
        """
        applied = []
        for wid in self.state.window_ids():
            if wid <= self.frontier:
                continue
            if wid != self.frontier + 1:
                break                       # in-order: wait for the gap
            hosts = self.state.window_hosts(wid)
            if len(hosts) < self.quorum:
                break
            decision, mode_used, votes = self._decide(wid, hosts)
            self.decided[wid] = decision
            self.frontier = wid
            if self.metrics is not None:
                self.metrics.inc("fleet.windows_decided")
            if decision != self.policy:
                swap = FleetSwap(wid, self.policy, decision, mode_used,
                                 votes, network_round)
                self.policy = decision
                self.swaps.append(swap)
                applied.append(swap)
                if apply_fn is not None:
                    apply_fn(decision, swap)
                if self.events is not None:
                    self.events.record("policy_swap", f"fleet/window{wid}",
                                       0, 0.0, 0.0, wid, decision)
                if self.metrics is not None:
                    self.metrics.inc("fleet.swaps")
        return applied

    def _decide(self, wid: int,
                hosts: dict[str, WindowDelta]) -> tuple[str, str, dict]:
        votes = {h: self.vote_of(d) for h, d in sorted(hosts.items())}
        tally: dict[str, float] = {}
        for vote, weight in votes.values():
            tally[vote] = tally.get(vote, 0.0) + weight
        total = math.fsum(tally.values())
        record = {h: [v, w] for h, (v, w) in votes.items()}
        if total <= 0.0:
            return self.policy, "quorum", record     # no dollars at stake
        winner = max(sorted(tally), key=lambda p: tally[p])
        if tally[winner] > 0.5 * total:
            return winner, "quorum", record
        if self.mode == "central":
            # centralized tiebreak: fleet-aggregated window dollars, same
            # hysteresis rule against the incumbent
            agg = self.state.fleet_window_dollars(wid)
            inc = self.policy
            best = min(agg, key=agg.get)
            if best != inc and agg[best] < (1.0 - self.hysteresis) * \
                    agg.get(inc, 0.0):
                return best, "tiebreak", record
        return self.policy, "quorum", record

    def snapshot(self) -> dict:
        return dict(policy=self.policy, quorum=self.quorum, mode=self.mode,
                    hysteresis=self.hysteresis, frontier=self.frontier,
                    windows_decided=len(self.decided),
                    swaps=[dataclasses.asdict(s) for s in self.swaps],
                    state=self.state.snapshot())


class Fleet:
    """N governed edge hosts over one origin store, acting as one fleet."""

    COORD = "coordinator"

    def __init__(self, store: Optional[ObjectStore] = None,
                 n_nodes: int = 4, capacity_bytes: float = 1 << 22,
                 policy: str = "lru",
                 policies: tuple[str, ...] = ONLINE_POLICIES,
                 window_span: float = 512.0, max_skew: float = 64.0,
                 hysteresis: float = 0.1, quorum: Optional[int] = None,
                 mode: str = "quorum", network: Optional[SimNetwork] = None,
                 gossip_every: Optional[int] = None, seed: int = 0,
                 events=None, metrics=None, price: str = "s3_internet",
                 keep_wire_log: bool = True):
        assert n_nodes >= 1
        self.store = store if store is not None else ObjectStore(price)
        self.network = network if network is not None else SimNetwork(seed)
        self.nodes = [
            FleetNode(f"edge{i}", self.store, capacity_bytes, policy,
                      policies, window_span, max_skew, events=events,
                      metrics=metrics, keep_wire_log=keep_wire_log)
            for i in range(n_nodes)]
        self._by_host = {n.host: n for n in self.nodes}
        self.coordinator = FleetCoordinator(
            n_nodes, policy, policies, hysteresis, quorum, mode,
            events=events, metrics=metrics)
        self.metrics = metrics
        self.gossip_every = gossip_every     # None = step() manually
        self._since_gossip = 0
        self._auto_t = 0.0

    # ------------------------------------------------------------------
    def node_of(self, key: str) -> FleetNode:
        return self.nodes[hash_partition(key, len(self.nodes))]

    def access(self, key: str, event_time: Optional[float] = None) -> bytes:
        """Route one request to its owning host by key hash."""
        if event_time is None:
            event_time = self._auto_t
        self._auto_t = max(self._auto_t, float(event_time)) + 1.0
        data = self.node_of(key).access(key, event_time)
        if self.gossip_every:
            self._since_gossip += 1
            if self._since_gossip >= self.gossip_every:
                self._since_gossip = 0
                self.step()
        return data

    # ------------------------------------------------------------------
    def step(self) -> list[FleetSwap]:
        """One gossip round: every node broadcasts its full state (anti-
        entropy — drops heal on the next round) to all peers and the
        coordinator; deliver with faults; merge; poll for decisions."""
        for node in self.nodes:
            frames = [encode_window_delta(d)
                      for d in node.state.deltas.values()]
            node.outbox.clear()
            for peer in self.nodes:
                if peer is node:
                    continue
                for f in frames:
                    self.network.send(node.host, peer.host, f)
            for f in frames:
                self.network.send(node.host, self.COORD, f)
        for dst, _src, frame in self.network.deliver():
            delta = decode_window_delta(frame)
            if dst == self.COORD:
                self.coordinator.ingest(delta)
            else:
                self._by_host[dst].state.merge(delta)
        return self.coordinator.poll(self._apply_swap, self.network.round)

    def _apply_swap(self, policy: str, swap: FleetSwap) -> None:
        for node in self.nodes:
            node.cache.set_policy(policy)    # no-op if already there

    def flush(self, max_rounds: int = 64) -> bool:
        """End-of-stream: close all open windows, then gossip until every
        participant (nodes + coordinator) holds the same digest. Returns
        True iff converged within `max_rounds`."""
        for node in self.nodes:
            node.flush()
        for _ in range(max_rounds):
            self.step()
            if self.converged():
                return True
        return self.converged()

    def converged(self) -> bool:
        """True when every participant (nodes + coordinator) holds the
        same digest. Frames still in flight cannot break this: a frame is
        a delta of its sender's state at send time, states only grow, and
        merge keeps the max seq — so once digests agree, anything still
        queued (delayed/duplicated copies) is stale on arrival."""
        digests = {n.state.digest() for n in self.nodes}
        digests.add(self.coordinator.state.digest())
        return len(digests) == 1

    # ------------------------------------------------------------------
    @property
    def policy(self) -> str:
        return self.coordinator.policy

    @property
    def swaps(self) -> list[FleetSwap]:
        return self.coordinator.swaps

    def dollars(self) -> float:
        """Fleet-wide realized bill: fsum over per-node BillingMeters."""
        return math.fsum(n.cache.meter.dollars for n in self.nodes)

    def audits(self) -> dict:
        """Per-host exact offline audits (None for traffic-less hosts);
        their observed dollars fsum to `dollars()` bit-for-bit (each
        host's audit reads its own meter, and a None host's meter is $0).
        """
        return {n.host: n.audit() for n in self.nodes}

    def fleet_shadow_totals(self) -> dict[str, float]:
        """Converged fleet-wide per-policy windowed shadow dollars, from
        the coordinator's merged gossip state."""
        return self.coordinator.state.fleet_totals()

    def snapshot(self) -> dict:
        return dict(
            n_nodes=len(self.nodes), policy=self.coordinator.policy,
            dollars=self.dollars(),
            window_span=self.nodes[0].window_span,
            max_skew=self.nodes[0].watermark.max_skew,
            coordinator=self.coordinator.snapshot(),
            network=self.network.snapshot(),
            shadow_totals=self.fleet_shadow_totals(),
            nodes={n.host: n.snapshot() for n in self.nodes})
