"""Table 1 / Fig. 3 — same trace, four real price vectors.

The Twitter twemcache stand-in (mean 243 B objects) replayed under
S3-cross-region / S3-internet / Azure / GCS pricing: as s* falls, more
objects become egress-dominated, H rises, and GDSF/LRU falls (paper:
0.82 -> 0.65). The regime is set by the price vector alone.

The budget axis of the regime map is computed parametrically: per price
vector ONE warm-started `exact_opt_uniform_sweep` run replaces the
per-budget exact solves, and all (policy x price x budget) heuristic cells
run as ONE compiled `sweep_jax` device program.
"""
from __future__ import annotations

import numpy as np

from repro.core import (PRICE_VECTORS, cost_foo, exact_opt_uniform_sweep,
                        heterogeneity, miss_costs, regret, simulate,
                        twemcache_like)
from repro.core.policies_jax import sweep_jax
from .common import emit, timed

ORDER = ["s3_cross_region", "s3_internet", "azure_internet", "gcs_internet"]


def run_table(n_requests=20000, budget_frac=0.3, seed=0):
    tr = twemcache_like(n_requests=n_requests, seed=seed)
    B = float(tr.sizes.sum() * budget_frac)
    rows = []
    for name in ORDER:
        pv = PRICE_VECTORS[name]
        costs = miss_costs(tr.sizes, pv)
        H = heterogeneity(tr.ids, costs)
        foo = cost_foo(tr, costs, B)
        lru = simulate("lru", tr, costs, B).dollars
        gdsf = simulate("gdsf", tr, costs, B).dollars
        r_lru = regret(lru, foo.lower)
        r_gdsf = regret(gdsf, foo.lower)
        rows.append(dict(price=name, sstar=pv.crossover_bytes, H=H,
                         lru_regret=r_lru, gdsf_regret=r_gdsf,
                         ratio=r_gdsf / max(r_lru, 1e-12),
                         bracket=foo.bracket))
    return rows


def run_budget_regime(n_requests=20000, seed=0,
                      budgets=(32, 64, 128, 256)):
    """Regret-vs-budget regime map, page-uniform exact reference.

    Exact OPT across all budgets costs one parametric solve per price
    vector; the (2 policies x 4 prices x K budgets) heuristic grid is one
    compiled program.
    """
    tr = twemcache_like(n_requests=n_requests, seed=seed)
    budgets = np.asarray(budgets, dtype=np.int64)
    cost_matrix = np.stack([miss_costs(tr.sizes, PRICE_VECTORS[name])
                            for name in ORDER])
    opt = np.stack([exact_opt_uniform_sweep(tr.ids, cost_matrix[i],
                                            budgets).dollars
                    for i in range(len(ORDER))])          # (P, K)
    grid = sweep_jax(["lru", "gdsf"], tr.ids, cost_matrix, budgets,
                     num_objects=tr.num_objects, sizes=tr.sizes)  # (2, P, K)
    reg = (grid - opt[None]) / np.maximum(opt[None], 1e-12)
    return budgets, reg


def main():
    rows, dt = timed(run_table, repeats=1)
    parts = []
    for r in rows:
        parts.append(f"{r['price']}:sstar={r['sstar']:.0f}B,H={r['H']:.3f},"
                     f"lruR={r['lru_regret']:.3f},ratio={r['ratio']:.2f}")
    emit("table1_crossover_twitter", dt, ";".join(parts))
    # monotonicity: H rises as s* falls
    Hs = [r["H"] for r in rows]
    emit("table1_H_monotone", 0.0,
         f"monotone={all(a <= b + 1e-9 for a, b in zip(Hs, Hs[1:]))}")

    # budget-axis regime map: exact sweep + one (2 x 4 x K) device grid
    (budgets, reg), dt_map = timed(run_budget_regime, repeats=1)
    parts = []
    for i, name in enumerate(ORDER):
        gdsf_reg = ";".join(f"B{b}={reg[1, i, k]:.3f}"
                            for k, b in enumerate(budgets))
        parts.append(f"{name}:{gdsf_reg}")
    emit("fig3_budget_regime_map", dt_map, "|".join(parts))
    return rows


if __name__ == "__main__":
    main()
