"""Table 1 / Fig. 3 — same trace, four real price vectors.

The Twitter twemcache stand-in (mean 243 B objects) replayed under
S3-cross-region / S3-internet / Azure / GCS pricing: as s* falls, more
objects become egress-dominated, H rises, and GDSF/LRU falls (paper:
0.82 -> 0.65). The regime is set by the price vector alone.
"""
from __future__ import annotations

import numpy as np

from repro.core import (PRICE_VECTORS, cost_foo, heterogeneity, miss_costs,
                        regret, simulate, twemcache_like)
from .common import emit, timed

ORDER = ["s3_cross_region", "s3_internet", "azure_internet", "gcs_internet"]


def run_table(n_requests=20000, budget_frac=0.3, seed=0):
    tr = twemcache_like(n_requests=n_requests, seed=seed)
    B = float(tr.sizes.sum() * budget_frac)
    rows = []
    for name in ORDER:
        pv = PRICE_VECTORS[name]
        costs = miss_costs(tr.sizes, pv)
        H = heterogeneity(tr.ids, costs)
        foo = cost_foo(tr, costs, B)
        lru = simulate("lru", tr, costs, B).dollars
        gdsf = simulate("gdsf", tr, costs, B).dollars
        r_lru = regret(lru, foo.lower)
        r_gdsf = regret(gdsf, foo.lower)
        rows.append(dict(price=name, sstar=pv.crossover_bytes, H=H,
                         lru_regret=r_lru, gdsf_regret=r_gdsf,
                         ratio=r_gdsf / max(r_lru, 1e-12),
                         bracket=foo.bracket))
    return rows


def main():
    rows, dt = timed(run_table, repeats=1)
    parts = []
    for r in rows:
        parts.append(f"{r['price']}:sstar={r['sstar']:.0f}B,H={r['H']:.3f},"
                     f"lruR={r['lru_regret']:.3f},ratio={r['ratio']:.2f}")
    emit("table1_crossover_twitter", dt, ";".join(parts))
    # monotonicity: H rises as s* falls
    Hs = [r["H"] for r in rows]
    emit("table1_H_monotone", 0.0,
         f"monotone={all(a <= b + 1e-9 for a, b in zip(Hs, Hs[1:]))}")
    return rows


if __name__ == "__main__":
    main()