"""cost-FOO bracket tightness on variable-size synthetic traces
(paper: median (U-L)/L ~ 0.04)."""
from __future__ import annotations

import numpy as np

from repro.core import PRICE_VECTORS, cost_foo, miss_costs, zipf_trace
from .common import emit, timed


def run_brackets(n_seeds=8):
    brackets = []
    for seed in range(n_seeds):
        tr = zipf_trace(n_objects=150, n_requests=3000, sigma=1.5,
                        mean_size=64 * 1024, seed=seed)
        costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
        B = float(np.quantile(tr.sizes, 0.9) * 30)
        brackets.append(cost_foo(tr, costs, B).bracket)
    return brackets


def main():
    brackets, dt = timed(run_brackets, repeats=1)
    emit("costfoo_bracket", dt,
         f"median={np.median(brackets):.4f};max={max(brackets):.4f};"
         f"n={len(brackets)}")
    return brackets


if __name__ == "__main__":
    main()