"""cost-FOO at CDN scale: bracket tightness, segment-tree rounding speedup,
epoch-decomposition scaling, and the end-to-end win over the pre-PR path.

Rows (all land in BENCH_costfoo.json; `ok=` rows are CI gates):

* ``costfoo_bracket`` — paper §4 tightness on small variable-size traces
  (median (U-L)/L ~ 0.04).
* ``costfoo_round_speedup_50k`` — the lazy range-add/range-min headroom
  tree (DESIGN.md §4) vs the quadratic ``round_fractional_reference``
  oracle on a long-gap scan workload, asserted bit-identical AND >= 5x.
* ``costfoo_scale_<T>`` — bracket / epochs / lp+round seconds as T grows
  on a fixed zipf shape: the decomposed solver's scaling curve.
* ``costfoo_epoch_bracket_valid`` — below the auto-decomposition
  threshold the default path is bit-identical to the monolithic LP, and
  forcing small epochs still yields a valid (lower <= monolithic) bound.
* ``costfoo_cdn200k_vs_prepr`` — full pipeline on a wiki-CDN-like
  T=200k trace vs a faithful replica of the pre-PR path (monolithic LP
  with Python-loop assembly + quadratic rounding), asserted >= 5x.
  ``COSTFOO_T`` scales it down for quick local runs (the 5x gate is only
  asserted at T >= 200k: the monolithic LP's superlinear cost is the
  point, and it has not diverged enough at small T).
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (PRICE_VECTORS, build_interval_arrays, cost_foo,
                        miss_costs, round_fractional,
                        round_fractional_reference, wiki_cdn_like,
                        zipf_trace)
from repro.core.opt_exact import Interval
from repro.core.trace import next_use_indices
from .common import Timing, emit, timed


def run_brackets(n_seeds=8):
    brackets = []
    for seed in range(n_seeds):
        tr = zipf_trace(n_objects=150, n_requests=3000, sigma=1.5,
                        mean_size=64 * 1024, seed=seed)
        costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
        B = float(np.quantile(tr.sizes, 0.9) * 30)
        brackets.append(cost_foo(tr, costs, B).bracket)
    return brackets


# ---------------------------------------------------------------------------
# pre-PR replica — the baseline the tentpole is measured against
# ---------------------------------------------------------------------------

def _prepr_lp_opt(ids, costs, sizes, B):
    """Faithful replica of the pre-optimization ``lp_opt``: monolithic LP
    over the whole trace, constraint matrix assembled with per-interval
    Python loops and per-instant bound tuples. Kept in the bench (not in
    src/) purely as the A/B baseline for ``costfoo_cdn200k_vs_prepr`` —
    the library path is `build_interval_arrays` + epoch decomposition."""
    from scipy import sparse
    from scipy.optimize import linprog

    ids = np.asarray(ids)
    T = len(ids)
    total = float(costs[ids].sum())
    nxt = next_use_indices(ids, int(ids.max()) + 1)
    intervals = []
    for t in range(T):
        u = int(nxt[t])
        if u < T:
            i = int(ids[t])
            intervals.append(Interval(t, u, i, float(costs[i]),
                                      float(sizes[i])))
    free_save = sum(iv.save for iv in intervals
                    if iv.u == iv.t + 1 and iv.size <= B)
    paid = [iv for iv in intervals if iv.u > iv.t + 1 and iv.size <= B]
    m = len(paid)
    nz = T - 1
    if m == 0 or nz <= 0:
        return total - free_save, free_save, np.zeros(0), paid
    save_scale = float(np.mean([iv.save for iv in paid])) or 1.0
    size_scale = float(np.mean([iv.size for iv in paid])) or 1.0
    rows, cols, vals = [], [], []
    for tau in range(1, T):
        rows.append(tau - 1); cols.append(m + tau - 1); vals.append(1.0)
        if tau + 1 <= T - 1:
            rows.append(tau); cols.append(m + tau - 1); vals.append(-1.0)
    for j, iv in enumerate(paid):
        rows.append(iv.t); cols.append(j); vals.append(-iv.size / size_scale)
        if iv.u <= T - 1:
            rows.append(iv.u - 1); cols.append(j)
            vals.append(iv.size / size_scale)
    A = sparse.csc_matrix((vals, (rows, cols)), shape=(nz, m + nz))
    c = np.concatenate([-np.array([iv.save / save_scale for iv in paid]),
                        np.zeros(nz)])
    zcap = np.array([max(B - sizes[ids[tau]], 0.0)
                     if sizes[ids[tau]] <= B else B
                     for tau in range(1, T)]) / size_scale
    bounds = [(0.0, 1.0)] * m + [(0.0, float(zc)) for zc in zcap]
    res = linprog(c, A_eq=A, b_eq=np.zeros(nz), bounds=bounds,
                  method="highs")
    if not res.success:
        raise RuntimeError(f"LP failed: {res.message}")
    savings = float(-res.fun) * save_scale + free_save
    return total - savings, savings, res.x[:m], paid


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def _scan_workload(T=50_000, n_objects=25_000, seed=0):
    """Worst case for the quadratic oracle: every reuse gap spans ~half the
    trace, so its per-interval numpy feasibility slice touches O(T) instants
    while the headroom tree pays O(log T)."""
    rng = np.random.default_rng(seed)
    ids = np.tile(np.arange(n_objects, dtype=np.int32), T // n_objects)
    sizes = rng.lognormal(np.log(64 * 1024), 1.1, n_objects)
    B = float(np.quantile(sizes, 0.9) * 120)
    costs = np.ones(n_objects)
    t, u, obj, save, size = build_interval_arrays(ids, costs, sizes)
    paid = [Interval(int(tt), int(uu), int(oo), float(sv), float(sz))
            for tt, uu, oo, sv, sz in zip(t, u, obj, save, size)]
    x = np.ones(len(paid))
    return ids, sizes, B, x, paid


def round_speedup(T=50_000):
    ids, sizes, B, x, paid = _scan_workload(T=T)
    fast, dt_fast = timed(round_fractional, ids, sizes, B, x, paid,
                          repeats=3)
    ref, dt_ref = timed(round_fractional_reference, ids, sizes, B, x, paid,
                        repeats=1)
    return fast, ref, dt_fast, dt_ref, len(paid)


def scaling_curve(Ts=(20_000, 50_000, 100_000, 200_000)):
    out = []
    for T in Ts:
        tr = zipf_trace(n_objects=2000, n_requests=T, sigma=1.1,
                        mean_size=64 * 1024, seed=0)
        costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
        B = float(np.quantile(tr.sizes, 0.9) * 60)
        t0 = time.perf_counter()
        r = cost_foo(tr, costs, B, policies=("gdsf",))
        dt = time.perf_counter() - t0
        out.append((T, r, dt))
    return out


def epoch_validity(T=20_000):
    tr = zipf_trace(n_objects=400, n_requests=T, sigma=1.2,
                    mean_size=48 * 1024, seed=3)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["s3_internet"])
    B = float(np.quantile(tr.sizes, 0.9) * 40)
    auto = cost_foo(tr, costs, B, policies=("gdsf",))       # T < threshold
    mono = cost_foo(tr, costs, B, policies=("gdsf",), epoch_len=T + 1)
    forced = cost_foo(tr, costs, B, policies=("gdsf",), epoch_len=5000)
    tol = 1e-6 * max(1.0, mono.lower)
    ok = (abs(auto.lower - mono.lower) <= tol
          and auto.upper == mono.upper
          and forced.lower <= mono.lower + tol
          and forced.lower <= forced.upper + 1e-9)
    return auto, mono, forced, ok


def cdn_vs_prepr(T=200_000, seed=0):
    tr = wiki_cdn_like(n_objects=3 * T // 10, n_requests=T, seed=seed)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
    B = float(np.quantile(tr.sizes, 0.9) * 400)

    t0 = time.perf_counter()
    r = cost_foo(tr, costs, B, policies=("gdsf",))
    dt_new = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, _, x, paid = _prepr_lp_opt(tr.ids, costs, tr.sizes, B)
    round_fractional_reference(tr.ids, tr.sizes, B, x, paid)
    dt_old = time.perf_counter() - t0
    return r, dt_new, dt_old


def main():
    brackets, dt = timed(run_brackets, repeats=1)
    emit("costfoo_bracket", dt,
         f"median={np.median(brackets):.4f};max={max(brackets):.4f};"
         f"n={len(brackets)}")

    # tentpole gate 1: the headroom tree beats the quadratic oracle >= 5x
    # on long-gap traces and agrees bit for bit
    fast, ref, dt_fast, dt_ref, m = round_speedup()
    speedup = dt_ref.min / dt_fast.min
    ok = fast == ref and speedup >= 5.0
    emit("costfoo_round_speedup_50k", dt_fast,
         f"ok={ok};speedup={speedup:.1f}x;tree_s={dt_fast.min:.3f};"
         f"ref_s={dt_ref.min:.3f};m={m};bit_identical={fast == ref}")
    assert ok, (speedup, fast, ref)

    # scaling curve: decomposed solver across trace lengths
    for T, r, dt in scaling_curve():
        p = r.profile
        emit(f"costfoo_scale_{T // 1000}k", Timing([dt]),
             f"bracket={r.bracket:.4f};epochs={p['epochs']};"
             f"lp_s={p['lp_seconds']:.2f};round_s={p['round_seconds']:.2f};"
             f"paid_m={p['paid_intervals']};"
             f"crossing={p['crossing_intervals']}")

    # tentpole gate 2: decomposition stays a valid bracket
    auto, mono, forced, ok = epoch_validity()
    emit("costfoo_epoch_bracket_valid", 0.0,
         f"ok={ok};auto_lower={auto.lower:.6g};mono_lower={mono.lower:.6g};"
         f"forced_lower={forced.lower:.6g};"
         f"forced_epochs={forced.profile['epochs']}")
    assert ok, (auto.lower, mono.lower, forced.lower)

    # tentpole gate 3: end-to-end >= 5x over the pre-PR monolithic path at
    # CDN scale (superlinear monolithic LP is what the decomposition kills)
    T = int(os.environ.get("COSTFOO_T", "200000"))
    r, dt_new, dt_old = cdn_vs_prepr(T=T)
    speedup = dt_old / dt_new
    gate = T >= 200_000
    ok = speedup >= 5.0 or not gate
    p = r.profile
    emit("costfoo_cdn200k_vs_prepr", Timing([dt_new]),
         f"ok={ok};speedup={speedup:.2f}x;new_s={dt_new:.2f};"
         f"prepr_s={dt_old:.2f};T={T};bracket={r.bracket:.4f};"
         f"epochs={p['epochs']};lp_s={p['lp_seconds']:.2f};"
         f"round_s={p['round_seconds']:.2f};gate_active={gate}")
    assert ok, (speedup, dt_new, dt_old)
    return brackets


if __name__ == "__main__":
    main()
