"""Fleet governance vs every fixed policy on the partitioned regime shift.

The fleet instantiation of the governance scenario (DESIGN.md §10): four
hosts hash-partition the regime-shift trace, the price vector flips across
s* mid-stream, and no fixed policy wins both phases on the partitions —
LRU wins the fee-dominated phase, LFU the egress-dominated one. A governed
fleet (sharded shadow panels -> gossiped `WindowDelta`s -> quorum swap)
must detect the flip from windowed evidence alone and land fleet-wide on
the post-flip winner.

Emits per-policy fixed-fleet dollars, the governed fleet's dollars /
regret / swap count (the within-10%-of-best-fixed acceptance check), and a
faulty-network variant (drop+duplicate+reorder+delay) asserting the swap
count stays bounded — hysteresis plus decide-once windows prevent churn no
matter how evidence is delivered. Also exports the converged fleet
snapshot to `benchmarks/out/fleet_snapshot.json`, which CI validates
against `tests/schemas/fleet.json`.
"""
from __future__ import annotations

import json
import math

from repro.egress.cache import EgressCache, ONLINE_POLICIES
from repro.fleet import Fleet, SimNetwork, hash_partition
from repro.online.scenario import regime_shift_scenario

from .common import OUT_DIR, emit, timed

# locked-in fleet scenario (tests/test_fleet.py uses the same parameters)
SCENARIO = dict(n_phase=3000, seed=0, n_big_active=12, big_bytes=1 << 18)
N_NODES = 4
FLEET_KW = dict(window_span=400.0, max_skew=32.0, gossip_every=100)


def run_fixed_fleet(sc, policy):
    store = sc.make_store()
    caches = [EgressCache(store, sc.capacity_bytes / N_NODES, policy,
                          consumer=f"edge{i}") for i in range(N_NODES)]
    hits = reqs = 0
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        c = caches[hash_partition(key, N_NODES)]
        h0 = c.hits
        c.get(key)
        hits += c.hits - h0
        reqs += 1
    return dict(policy=policy,
                dollars=math.fsum(c.meter.dollars for c in caches),
                hit_rate=hits / reqs)


def run_governed_fleet(sc, network=None, seed=1):
    store = sc.make_store()
    fleet = Fleet(store=store, n_nodes=N_NODES,
                  capacity_bytes=sc.capacity_bytes / N_NODES,
                  policy="lru", network=network, seed=seed, **FLEET_KW)
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        fleet.access(key, event_time=t)
    fleet.flush()
    return fleet


def run_panel():
    sc = regime_shift_scenario(**SCENARIO)
    fixed = {p: run_fixed_fleet(sc, p) for p in ONLINE_POLICIES}
    fleet = run_governed_fleet(sc)
    faulty_net = SimNetwork(seed=3, drop=0.25, duplicate=0.3, reorder=0.5,
                            max_delay=2)
    faulty = run_governed_fleet(sc, network=faulty_net)
    return dict(scenario=sc, fixed=fixed, fleet=fleet, faulty=faulty)


def main():
    res, dt = timed(run_panel, repeats=1)
    fixed, fleet, faulty = res["fixed"], res["fleet"], res["faulty"]
    best = min(fixed.values(), key=lambda r: r["dollars"])
    for p, r in fixed.items():
        reg = (r["dollars"] - best["dollars"]) / best["dollars"]
        emit(f"fleet_fixed_{p}", 0.0,
             f"dollars={r['dollars']:.6f};regret_vs_best={reg:.3f};"
             f"hit_rate={r['hit_rate']:.3f}")

    g = fleet.dollars()
    greg = (g - best["dollars"]) / best["dollars"]
    emit("fleet_governed", dt,
         f"dollars={g:.6f};regret_vs_best={greg:.3f};"
         f"best_fixed={best['policy']};final={fleet.policy};"
         f"swaps={len(fleet.swaps)};converged={fleet.converged()}")
    emit("fleet_within_10pct", 0.0, f"ok={greg <= 0.10}")

    # billing identity: realized fleet bill == fsum of per-node audits
    audits = fleet.audits()
    audit_sum = math.fsum(a.observed_dollars for a in audits.values()
                          if a is not None)
    emit("fleet_billing_reconciles", 0.0,
         f"ok={g == audit_sum};fleet={g!r};audits={audit_sum!r}")

    f = faulty.dollars()
    freg = (f - best["dollars"]) / best["dollars"]
    ns = faulty.network.snapshot()
    emit("fleet_governed_faulty", 0.0,
         f"dollars={f:.6f};regret_vs_best={freg:.3f};"
         f"swaps={len(faulty.swaps)};converged={faulty.converged()};"
         f"dropped={ns['dropped']};duplicated={ns['duplicated']};"
         f"reordered={ns['reordered']}")
    emit("fleet_faulty_swaps_bounded", 0.0,
         f"ok={len(faulty.swaps) <= 3};swaps={len(faulty.swaps)}")

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / "fleet_snapshot.json"
    path.write_text(json.dumps(fleet.snapshot(), indent=2) + "\n")
    emit("fleet_snapshot_export", 0.0, f"path={path.name}")


if __name__ == "__main__":
    main()
