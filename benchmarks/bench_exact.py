"""Exact-reference validation at benchmark scale: the paper's 250-instance
cent-exact brute-force check (flow == state DP) plus LP integrality."""
from __future__ import annotations

import numpy as np

from repro.core import dp_opt_uniform, exact_opt_uniform, lp_opt
from .common import emit, timed


def run_250():
    rng = np.random.default_rng(0)
    worst = 0.0
    for _ in range(250):
        T = int(rng.integers(4, 13))
        N = int(rng.integers(2, 6))
        B = int(rng.integers(1, 4))
        ids = rng.integers(0, N, T).astype(np.int32)
        costs = rng.integers(1, 100, N).astype(float)
        f = exact_opt_uniform(ids, costs, B).dollars
        d = dp_opt_uniform(ids, costs, B)
        worst = max(worst, abs(f - d))
    return worst


def main():
    worst, dt = timed(run_250, repeats=1)
    emit("exact_250_bruteforce", dt, f"worst_abs_err={worst:.2e};cent_exact={worst < 1e-6}")

    rng = np.random.default_rng(1)
    ids = rng.integers(0, 100, 2000).astype(np.int32)
    costs = rng.lognormal(0, 2, 100)
    (res, dt2) = timed(lambda: lp_opt(ids, costs, np.ones(100), 12.0), repeats=1)
    x = res[2]
    integral = bool(np.all((x < 1e-6) | (x > 1 - 1e-6)))
    emit("lp_integrality_2k", dt2, f"integral_vertex={integral}")
    return None


if __name__ == "__main__":
    main()