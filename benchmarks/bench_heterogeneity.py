"""Fig. 1 — heterogeneity-regret law.

LRU's dollar-regret rises with miss-cost dispersion H (paper: Spearman
0.87); cost-aware GDSF's median regret is ~0.13x LRU's where H >= 0.5.
Uniform-size pages, costs assigned independently of popularity, exact OPT.
"""
from __future__ import annotations

import numpy as np

from repro.core import (Trace, exact_opt_uniform, heterogeneity, regret,
                        simulate)
from .common import emit, spearman, timed


def run_sweep(n_points=24, T=4000, N=150, B=32, seed0=100):
    rows = []
    for j in range(n_points):
        rng = np.random.default_rng(seed0 + j)
        sigma = 3.5 * j / max(1, n_points - 1)   # cost dispersion knob
        ids = _zipf_ids(rng, N, T, alpha=1.0)
        costs = np.exp(rng.normal(0.0, sigma, N))
        tr = Trace(ids=ids, sizes=np.ones(N))
        H = heterogeneity(ids, costs)
        opt = exact_opt_uniform(ids, costs, B).dollars
        r_lru = regret(simulate("lru", tr, costs, float(B)).dollars, opt)
        r_gdsf = regret(simulate("gdsf", tr, costs, float(B)).dollars, opt)
        rows.append((H, r_lru, r_gdsf))
    return rows


def _zipf_ids(rng, n, T, alpha):
    p = np.arange(1, n + 1, dtype=float) ** (-alpha)
    p /= p.sum()
    return rng.choice(n, size=T, p=p).astype(np.int32)


def main():
    rows, dt = timed(run_sweep, repeats=1)
    H = np.array([r[0] for r in rows])
    lru = np.array([r[1] for r in rows])
    gdsf = np.array([r[2] for r in rows])
    rho = spearman(H, lru)
    hi = H >= 0.5
    ratio = (np.median(gdsf[hi]) / max(np.median(lru[hi]), 1e-12)
             if hi.any() else float("nan"))
    emit("fig1_heterogeneity_law", dt,
         f"spearman_H_lru={rho:.3f};gdsf_over_lru_med@H>=0.5={ratio:.3f};"
         f"points={len(rows)}")
    return {"spearman": rho, "gdsf_over_lru": ratio, "rows": rows}


if __name__ == "__main__":
    main()