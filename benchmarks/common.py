"""Shared benchmark utilities: timing + CSV emission (name,us_per_call,derived).

Every `emit` row is also collected in-process so `run.py` can write a
machine-readable `BENCH_<name>.json` next to the CSV stream — the artifact
the perf trajectory is tracked with across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

# rows collected since the last `reset_records()`: (name, seconds, derived)
_RECORDS: list[dict] = []

OUT_DIR = pathlib.Path(__file__).resolve().parent / "out"


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, seconds_per_call)."""
    fn(*args, **kwargs)  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def emit(name: str, seconds: float, derived: str):
    _RECORDS.append(dict(name=name, us_per_call=seconds * 1e6, derived=derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def reset_records() -> None:
    _RECORDS.clear()


def write_json(bench: str) -> pathlib.Path:
    """Dump the rows emitted since the last reset to BENCH_<bench>.json."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"BENCH_{bench}.json"
    payload = dict(bench=bench, generated=time.strftime("%Y-%m-%dT%H:%M:%S"),
                   rows=list(_RECORDS))
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def spearman(x, y) -> float:
    import numpy as np
    rx = np.argsort(np.argsort(x)).astype(float)
    ry = np.argsort(np.argsort(y)).astype(float)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / denom) if denom else 0.0
