"""Shared benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
from __future__ import annotations

import time


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, seconds_per_call)."""
    fn(*args, **kwargs)  # warm
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def spearman(x, y) -> float:
    import numpy as np
    rx = np.argsort(np.argsort(x)).astype(float)
    ry = np.argsort(np.argsort(y)).astype(float)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / denom) if denom else 0.0