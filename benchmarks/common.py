"""Shared benchmark utilities: timing + CSV emission (name,us_per_call,derived).

Every `emit` row is also collected in-process so `run.py` can write a
machine-readable `BENCH_<name>.json` next to the CSV stream — the artifact
the perf trajectory is tracked with across PRs. `timed` returns a `Timing`
(a float carrying the per-repeat samples), so rows emitted from it record
min/mean/std and the repeat count — one averaged scalar is not
statistically interpretable across PRs.
"""
from __future__ import annotations

import json
import math
import pathlib
import time

# rows collected since the last `reset_records()`: (name, seconds, derived)
_RECORDS: list[dict] = []

OUT_DIR = pathlib.Path(__file__).resolve().parent / "out"


class Timing(float):
    """Mean seconds-per-call that also carries the per-repeat samples, so
    it drops into existing arithmetic (ratios, req/s) unchanged while
    `emit` can record the spread."""

    times: tuple[float, ...]

    def __new__(cls, times):
        times = tuple(float(t) for t in times)
        self = super().__new__(cls, sum(times) / len(times))
        self.times = times
        return self

    @property
    def min(self) -> float:
        return min(self.times)

    @property
    def mean(self) -> float:
        return float(self)

    @property
    def std(self) -> float:
        m = self.mean
        return math.sqrt(sum((t - m) ** 2 for t in self.times)
                         / len(self.times))

    def stats(self) -> dict:
        return dict(repeats=len(self.times), min_us=self.min * 1e6,
                    mean_us=self.mean * 1e6, std_us=self.std * 1e6,
                    samples_us=[t * 1e6 for t in self.times])


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, Timing) — mean seconds-per-call + per-repeat
    samples (each repeat timed individually)."""
    fn(*args, **kwargs)  # warm
    out = None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    return out, Timing(times)


def emit(name: str, seconds: float, derived: str):
    row = dict(name=name, us_per_call=seconds * 1e6, derived=derived)
    if isinstance(seconds, Timing):
        row["timing"] = seconds.stats()
    _RECORDS.append(row)
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def reset_records() -> None:
    _RECORDS.clear()


def write_json(bench: str) -> pathlib.Path:
    """Dump the rows emitted since the last reset to BENCH_<bench>.json."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"BENCH_{bench}.json"
    payload = dict(bench=bench, generated=time.strftime("%Y-%m-%dT%H:%M:%S"),
                   rows=list(_RECORDS))
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def spearman(x, y) -> float:
    import numpy as np
    rx = np.argsort(np.argsort(x)).astype(float)
    ry = np.argsort(np.argsort(y)).astype(float)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / denom) if denom else 0.0
