"""Fig. 4 — Wikipedia CDN arm (large objects, H = 12-18).

The wiki-CDN stand-in (mean ~37 KB, max ~94 MB, one-hit-wonder tail) under
the four price vectors: GDSF/LRU regret ratio falls monotonically as s*
drops (paper: 0.65 -> 0.45), with modest absolute LRU regret (3-7%) because
low reuse makes much of the bill unavoidable.
"""
from __future__ import annotations

import numpy as np

from repro.core import (PRICE_VECTORS, cost_foo, heterogeneity, miss_costs,
                        regret, simulate, wiki_cdn_like)
from .common import emit, timed

ORDER = ["s3_cross_region", "s3_internet", "azure_internet", "gcs_internet"]


def run_cdn(n_requests=20000, budget_frac=0.02, seed=0):
    tr = wiki_cdn_like(n_requests=n_requests, seed=seed)
    B = float(tr.sizes.sum() * budget_frac)
    rows = []
    for name in ORDER:
        pv = PRICE_VECTORS[name]
        costs = miss_costs(tr.sizes, pv)
        foo = cost_foo(tr, costs, B)
        lru = simulate("lru", tr, costs, B).dollars
        gdsf = simulate("gdsf", tr, costs, B).dollars
        r_lru = regret(lru, foo.lower)
        r_gdsf = regret(gdsf, foo.lower)
        rows.append(dict(price=name, sstar=pv.crossover_bytes,
                         H=heterogeneity(tr.ids, costs),
                         lru_regret=r_lru, gdsf_regret=r_gdsf,
                         ratio=r_gdsf / max(r_lru, 1e-12),
                         bracket=foo.bracket,
                         reuse=tr.reuse_fraction()))
    return rows


def main():
    rows, dt = timed(run_cdn, repeats=1)
    parts = [f"{r['price']}:H={r['H']:.1f},lruR={r['lru_regret']:.3f},"
             f"ratio={r['ratio']:.2f}" for r in rows]
    emit("fig4_cdn", dt, ";".join(parts))
    ratios = [r["ratio"] for r in rows]
    emit("fig4_ratio_falls_with_sstar", 0.0,
         f"first={ratios[0]:.2f};last={ratios[-1]:.2f};"
         f"falls={ratios[-1] < ratios[0]}")

    # the CDN arm at decomposition scale: 3x the requests (catalog scaled
    # with it so the one-hit tail keeps its share), one price vector — the
    # epoch-decomposed solver keeps the bracket useful where the
    # monolithic LP would dominate wall-clock (DESIGN.md §4.2)
    tr = wiki_cdn_like(n_objects=18_000, n_requests=60_000, seed=0)
    costs = miss_costs(tr.sizes, PRICE_VECTORS["gcs_internet"])
    B = float(tr.sizes.sum() * 0.02)
    foo, dt = timed(cost_foo, tr, costs, B, policies=("gdsf",), repeats=1)
    p = foo.profile
    emit("fig4_cdn_60k_decomposed", dt,
         f"bracket={foo.bracket:.4f};epochs={p['epochs']};"
         f"lp_s={p['lp_seconds']:.2f};round_s={p['round_seconds']:.2f};"
         f"gdsf_regret={regret(simulate('gdsf', tr, costs, B).dollars, foo.lower):.3f}")
    return rows


if __name__ == "__main__":
    main()