"""JAX lax.scan policy-replay throughput vs the Python reference, plus the
vmapped sweeps — the TPU-native form of the paper's grids.

Two sweep shapes: the original (price x budget) batch for one policy, and
the full (6 policies x 4 prices x 4 budgets) panel as ONE compiled program
(stacked `PolicyWeights` as a third vmap axis)."""
from __future__ import annotations

import numpy as np

from repro.core import Trace, simulate
from repro.core.policies_jax import (POLICY_WEIGHTS, simulate_jax, sweep_jax)
from .common import emit, timed


def main():
    rng = np.random.default_rng(0)
    T, N, B = 20_000, 500, 64
    ids = rng.integers(0, N, T).astype(np.int32)
    costs = 2.0 ** rng.integers(0, 12, N).astype(np.float64)
    tr = Trace(ids=ids, sizes=np.ones(N))

    _, dt_py = timed(lambda: simulate("gdsf", tr, costs, float(B)), repeats=1)
    _, dt_jax = timed(lambda: simulate_jax("gdsf", ids, costs, B,
                                           num_objects=N), repeats=3)
    emit("policy_python_20k", dt_py, f"req_per_s={T/dt_py:.0f}")
    emit("policy_jax_scan_20k", dt_jax,
         f"req_per_s={T/dt_jax:.0f};speedup_vs_py={dt_py/dt_jax:.2f}x")

    # batched 4 price vectors x 4 budgets in one device program
    cost_matrix = np.stack([costs * (10 ** k) for k in range(4)])
    budgets = np.array([16, 32, 64, 128])
    out, dt_sweep = timed(lambda: sweep_jax("gdsf", ids, cost_matrix, budgets,
                                            num_objects=N), repeats=1)
    cells = out.size
    emit("policy_jax_sweep_16cells", dt_sweep,
         f"cell_per_s={cells/dt_sweep:.2f};req_per_s={cells*T/dt_sweep:.0f}")

    # the full policy panel: 6 policies x 4 prices x 4 budgets, ONE program
    policies = list(POLICY_WEIGHTS)
    out3, dt_grid = timed(lambda: sweep_jax(policies, ids, cost_matrix,
                                            budgets, num_objects=N),
                          repeats=1)
    cells = out3.size
    # per-policy sweeps for reference: 6 separate compiled programs
    _, dt_loop = timed(
        lambda: [sweep_jax(p, ids, cost_matrix, budgets, num_objects=N)
                 for p in policies], repeats=1)
    emit("policy_jax_grid_96cells", dt_grid,
         f"cell_per_s={cells/dt_grid:.2f};req_per_s={cells*T/dt_grid:.0f};"
         f"one_program_speedup={dt_loop/dt_grid:.2f}x")
    return None


if __name__ == "__main__":
    main()
