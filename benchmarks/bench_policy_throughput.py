"""JAX lax.scan policy-replay throughput vs the Python reference, plus the
vmapped sweeps — the TPU-native form of the paper's grids.

Two sweep shapes: the original (price x budget) batch for one policy, and
the full (6 policies x 4 prices x 4 budgets) panel as ONE compiled program
(stacked `PolicyWeights` as a third vmap axis).

Obs additions (DESIGN.md §9): `sweep_jax(profile=...)` separates compile
time from execute time (cold vs warm), and tracing overhead is measured
at two granularities. The acceptance gate is the governed `ServeEngine`
loop (the acceptance criterion's workload): span tracer + decision event
log enabled must cost < 10% over the untraced engine, and a falsy (no-op)
tracer must cost ~0. The raw `EgressCache` replay is also reported — the
worst-case per-access cost of full-fidelity publishing (every access is
dict lookups + a heap push, so ~µs of spans/events is a large *fraction*
there; it is the absolute ns/access that transfers to real workloads)."""
from __future__ import annotations

import numpy as np

from repro.core import Trace, simulate
from repro.core.policies_jax import (POLICY_WEIGHTS, simulate_jax, sweep_jax)
from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore
from repro.obs import EventLog, MetricsRegistry, NullTracer, Tracer
from .common import Timing, emit, timed


def _egress_replay(cache: EgressCache, keys: list) -> None:
    get = cache.get
    for k in keys:
        get(k)


def trace_overhead(T: int = 20_000, n_objects: int = 256,
                   obj_bytes: int = 4096, cache_objects: int = 64,
                   seed: int = 0):
    """Per-access cost of the obs publishers on the live egress cache."""
    rng = np.random.default_rng(seed)
    store = ObjectStore("s3_internet")
    for i in range(n_objects):
        store.put(f"o{i}", bytes(obj_bytes))
    keys = [f"o{z % n_objects}" for z in rng.zipf(1.2, T)]
    cap = float(cache_objects * obj_bytes)

    def replay(tracer=None, events=None, consumer="bench"):
        cache = EgressCache(store, cap, "gdsf", consumer=consumer,
                            metrics=MetricsRegistry(), tracer=tracer,
                            events=events)
        return timed(_egress_replay, cache, keys, repeats=3)

    _, dt_off = replay(consumer="bench_off")
    _, dt_null = replay(tracer=NullTracer(), consumer="bench_null")
    _, dt_on = replay(tracer=Tracer(max_spans=T), events=EventLog(T),
                      consumer="bench_on")
    return dt_off, dt_null, dt_on


def serve_trace_overhead(rounds: int = 4, hot_prompts: int = 3,
                         repeats: int = 5):
    """Tracing overhead on a full governed ServeEngine loop — the
    acceptance workload: requests through the egress-billed prefix cache
    with the dollar governor live. One engine per config; a warm-up pass
    absorbs jit compilation, then repeats are INTERLEAVED across configs
    (sequential blocks would fold clock/allocator drift into the
    comparison) and min-per-config is the robust estimator."""
    import time as _time

    import jax
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("gemma3-4b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(hot_prompts)]

    def serve_rounds(engine):
        rid = 0
        for _ in range(rounds):
            reqs = [Request(rid + i, p, max_new_tokens=4)
                    for i, p in enumerate(prompts)]
            rid += len(reqs)
            engine.serve(reqs)

    def make(tracer=None, events=None):
        return ServeEngine(model, params, prefix_cache_bytes=1 << 22,
                           policy="gdsf", govern=True, governor_window=8,
                           tracer=tracer, events=events)

    engines = dict(off=make(), null=make(tracer=NullTracer()),
                   on=make(tracer=Tracer(max_spans=100_000),
                           events=EventLog(100_000)))
    samples: dict[str, list[float]] = {k: [] for k in engines}
    for e in engines.values():      # compile + fill the prefix cache
        serve_rounds(e)
    for _ in range(repeats):
        for k, e in engines.items():
            t0 = _time.perf_counter()
            serve_rounds(e)
            samples[k].append(_time.perf_counter() - t0)
    return (Timing(samples["off"]), Timing(samples["null"]),
            Timing(samples["on"]))


def shadow_panel_overhead(T: int = 40_000, n_objects: int = 512,
                          cache_objects: int = 96, repeats: int = 5,
                          seed: int = 0):
    """ns/access of the shadow panel's hit fast path vs the generic path.

    `ShadowCache.access` short-circuits LRU/LFU priority recomputes on
    hits; `_GenericShadow` restores the pre-fast-path body (always route
    through `_priority` via `_touch`). Both panels replay the identical
    event stream — counterfactual dollars must agree exactly, and the
    fast panel must not be slower."""
    import time as _time

    from repro.online.shadow import ShadowCache, ShadowPanel

    class _GenericShadow(ShadowCache):
        def access(self, key: str, nbytes: int, miss_cost: float) -> bool:
            self._clock += 1
            self._freq[key] = self._freq.get(key, 0) + 1
            if key in self._sizes:
                self.hits += 1
                self._touch(key, nbytes, miss_cost)
                return True
            self.misses += 1
            self.dollars += miss_cost
            if nbytes <= self.capacity:
                self._evict_until_fits(nbytes)
                self._sizes[key] = nbytes
                self.used += nbytes
                self._touch(key, nbytes, miss_cost)
            return False

    rng = np.random.default_rng(seed)
    nbytes_by_obj = rng.integers(1024, 8192, n_objects)
    events = [(f"o{z % n_objects}", int(nbytes_by_obj[z % n_objects]))
              for z in rng.zipf(1.1, T)]
    cap = float(cache_objects * int(nbytes_by_obj.mean()))

    def make_panels():
        fast = ShadowPanel(cap)
        generic = ShadowPanel(cap)
        generic.shadows = {p: _GenericShadow(p, cap)
                           for p in generic.policies}
        return fast, generic

    def replay(panel):
        shadows = list(panel.shadows.values())
        for key, nb in events:
            mc = nb * 1e-9
            for sh in shadows:
                sh.access(key, nb, mc)

    # correctness first: identical counterfactual dollars per policy
    fast, generic = make_panels()
    replay(fast)
    replay(generic)
    assert fast.dollars() == generic.dollars(), (
        fast.dollars(), generic.dollars())

    # timing: fresh panels per repeat, interleaved to dodge clock drift
    samples: dict[str, list[float]] = {"fast": [], "generic": []}
    for _ in range(repeats):
        fast, generic = make_panels()
        for name, panel in (("fast", fast), ("generic", generic)):
            t0 = _time.perf_counter()
            replay(panel)
            samples[name].append(_time.perf_counter() - t0)
    return Timing(samples["fast"]), Timing(samples["generic"]), len(events)


def main():
    rng = np.random.default_rng(0)
    T, N, B = 20_000, 500, 64
    ids = rng.integers(0, N, T).astype(np.int32)
    costs = 2.0 ** rng.integers(0, 12, N).astype(np.float64)
    tr = Trace(ids=ids, sizes=np.ones(N))

    _, dt_py = timed(lambda: simulate("gdsf", tr, costs, float(B)), repeats=1)
    _, dt_jax = timed(lambda: simulate_jax("gdsf", ids, costs, B,
                                           num_objects=N), repeats=3)
    emit("policy_python_20k", dt_py, f"req_per_s={T/dt_py:.0f}")
    emit("policy_jax_scan_20k", dt_jax,
         f"req_per_s={T/dt_jax:.0f};speedup_vs_py={dt_py/dt_jax:.2f}x")

    # batched 4 price vectors x 4 budgets in one device program, with the
    # compile/execute split (cold then warm — warm compile hits the cache)
    cost_matrix = np.stack([costs * (10 ** k) for k in range(4)])
    budgets = np.array([16, 32, 64, 128])
    cold, warm = {}, {}
    sweep_jax("gdsf", ids, cost_matrix, budgets, num_objects=N, profile=cold)
    out = sweep_jax("gdsf", ids, cost_matrix, budgets, num_objects=N,
                    profile=warm)
    cells = out.size
    emit("policy_jax_sweep_16cells", warm["execute_s"],
         f"cell_per_s={cells/warm['execute_s']:.2f};"
         f"req_per_s={cells*T/warm['execute_s']:.0f}")
    emit("policy_jax_sweep_profile", cold["compile_s"] + cold["execute_s"],
         f"compile_s={cold['compile_s']:.3f};execute_s={cold['execute_s']:.4f};"
         f"warm_compile_s={warm['compile_s']:.4f};"
         f"compile_frac={cold['compile_s']/(cold['compile_s']+cold['execute_s']):.3f}")

    # the full policy panel: 6 policies x 4 prices x 4 budgets, ONE program
    policies = list(POLICY_WEIGHTS)
    out3, dt_grid = timed(lambda: sweep_jax(policies, ids, cost_matrix,
                                            budgets, num_objects=N),
                          repeats=1)
    cells = out3.size
    # per-policy sweeps for reference: 6 separate compiled programs
    _, dt_loop = timed(
        lambda: [sweep_jax(p, ids, cost_matrix, budgets, num_objects=N)
                 for p in policies], repeats=1)
    emit("policy_jax_grid_96cells", dt_grid,
         f"cell_per_s={cells/dt_grid:.2f};req_per_s={cells*T/dt_grid:.0f};"
         f"one_program_speedup={dt_loop/dt_grid:.2f}x")

    # obs overhead, acceptance gate: governed ServeEngine loop (<10% on,
    # ~0% with the no-op publisher)
    dt_off, dt_null, dt_on = serve_trace_overhead()
    ov_on = dt_on.min / dt_off.min - 1.0
    ov_null = dt_null.min / dt_off.min - 1.0
    emit("serve_trace_overhead_governed", dt_on,
         f"base_us={dt_off*1e6:.0f};overhead_on={ov_on:.3f};"
         f"overhead_null={ov_null:.3f};ok={ov_on < 0.10 and ov_null < 0.02}")

    # worst case: raw per-access publisher cost on the bare egress cache
    # loop (reported in absolute ns/access — the number that transfers)
    T = 20_000
    dt_off, dt_null, dt_on = trace_overhead(T=T)
    emit("egress_trace_cost_20k", dt_on,
         f"base_ns_per_access={dt_off/T*1e9:.0f};"
         f"traced_add_ns_per_access={(dt_on-dt_off)/T*1e9:.0f};"
         f"null_add_ns_per_access={(dt_null-dt_off)/T*1e9:.0f}")

    # shadow panel hit fast path: same dollars as the generic priority
    # path (asserted inside), and ns/access must not regress (10% noise
    # margin on interleaved min-of-repeats)
    dt_fast, dt_generic, n_ev = shadow_panel_overhead()
    ok = dt_fast.min <= dt_generic.min * 1.10
    emit("shadow_panel_ns_access", dt_fast,
         f"fast_ns={dt_fast.min/n_ev*1e9:.0f};"
         f"generic_ns={dt_generic.min/n_ev*1e9:.0f};"
         f"speedup={dt_generic.min/dt_fast.min:.3f}x;ok={ok}")
    assert ok, (dt_fast.min, dt_generic.min)
    return None


if __name__ == "__main__":
    main()
