"""Benchmark entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and writes
a machine-readable ``benchmarks/out/BENCH_<name>.json`` per module so the
perf trajectory is tracked across PRs:

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run crossover  # one
"""
from __future__ import annotations

import sys

from . import (bench_cdn, bench_contention, bench_costfoo, bench_crossover,
               bench_exact, bench_fleet, bench_flow_scale, bench_governor,
               bench_heterogeneity, bench_kernels, bench_policy_throughput,
               common)

ALL = {
    "exact": bench_exact.main,                    # §2 integrality/brute force
    "heterogeneity": bench_heterogeneity.main,    # Fig. 1
    "contention": bench_contention.main,          # Fig. 2
    "costfoo": bench_costfoo.main,                # §4 bracket
    "crossover": bench_crossover.main,            # Table 1 / Fig. 3
    "cdn": bench_cdn.main,                        # Fig. 4
    "flow_scale": bench_flow_scale.main,          # §6 scale + parametric sweep
    "policy_throughput": bench_policy_throughput.main,  # JAX replay engine
    "kernels": bench_kernels.main,                # Pallas vs oracle
    "governor": bench_governor.main,              # online governance (§8)
    "fleet": bench_fleet.main,                    # fleet governance (§10)
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        sys.exit(f"unknown benchmark(s) {unknown}; choose from: "
                 + ", ".join(ALL))
    print("name,us_per_call,derived")
    for n in names:
        common.reset_records()
        ALL[n]()
        common.write_json(n)


if __name__ == "__main__":
    main()
