"""Pallas kernels (interpret mode on CPU) vs their jnp oracles — correctness
at benchmark scale + oracle timing. On-TPU timing requires real hardware;
the dry-run covers the compiled path."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import emit, timed


def main():
    rng = np.random.default_rng(0)
    T, N = 100_000, 4096
    ids = jnp.asarray(rng.integers(0, N, T).astype(np.int32))

    out_ref, dt_ref = timed(
        lambda: np.asarray(ref.next_use_ref(ids, N)), repeats=1)
    out_k, dt_k = timed(
        lambda: np.asarray(ops.next_use(ids, N, block_t=4096)), repeats=1)
    emit("kernel_next_use_100k", dt_k,
         f"oracle_us={dt_ref*1e6:.0f};match={bool((out_ref==out_k).all())}")

    scores = jnp.asarray(rng.standard_normal(65536).astype(np.float32))
    touch = jnp.asarray(rng.integers(0, 1 << 20, 65536).astype(np.int32))
    mask = jnp.asarray(rng.random(65536) < 0.7)
    (gi, gv), dt_e = timed(
        lambda: ops.evict_argmin(scores, touch, mask, block_n=8192), repeats=1)
    wi, wv = ref.evict_argmin_ref(scores, touch, mask)
    emit("kernel_evict_argmin_64k", dt_e,
         f"match={int(gi)==int(wi)};victim={int(gi)}")

    deltas = jnp.asarray(rng.integers(-3, 4, 100_000).astype(np.float32))
    occ_k, dt_o = timed(
        lambda: np.asarray(ops.interval_occupancy(deltas, block_t=8192)),
        repeats=1)
    occ_r = np.cumsum(np.asarray(deltas))
    emit("kernel_interval_occupancy_100k", dt_o,
         f"allclose={bool(np.allclose(occ_k, occ_r, rtol=1e-5, atol=1e-3))}")

    # occupancy + worst excess over zcap in one pass (cost_foo validate=True)
    zcap = jnp.asarray(rng.integers(0, 6, 100_000).astype(np.float32))
    (occ_f, ex_f), dt_f = timed(
        lambda: ops.occupancy_feasible(deltas, zcap, block_t=8192), repeats=1)
    occ_w, ex_w = ref.occupancy_feasible_ref(deltas, zcap)
    ok = (np.allclose(np.asarray(occ_f), np.asarray(occ_w), rtol=1e-5,
                      atol=1e-3)
          and abs(float(ex_f) - float(ex_w)) < 1e-3)
    emit("kernel_occupancy_feasible_100k", dt_f,
         f"match={ok};excess={float(ex_f):.1f}")
    return None


if __name__ == "__main__":
    main()