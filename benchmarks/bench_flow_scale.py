"""Exact-optimum scalability: interval LP (sparse difference form) vs the
min-cost-flow solver, the paper's 1e5-request scale-stability check (LRU
regret unchanged at 5x the window), and the parametric budget sweep —
exact OPT for 16 budgets from ONE warm-started solve, asserted >=5x faster
than 16 independent solves and matching them to 1e-6 relative."""
from __future__ import annotations

import numpy as np

from repro.core import (Trace, exact_opt_uniform, exact_opt_uniform_sweep,
                        lp_opt, regret, simulate)
from .common import emit, timed


def main():
    rng = np.random.default_rng(0)
    N, B = 2000, 64

    # solver agreement + timing at the paper's 20k window
    ids20 = rng.integers(0, N, 20_000).astype(np.int32)
    costs = rng.lognormal(0, 2, N)
    (r20, dt_flow) = timed(lambda: exact_opt_uniform(ids20, costs, B),
                           repeats=1)
    (lp20, dt_lp) = timed(lambda: lp_opt(ids20, costs, np.ones(N), float(B)),
                          repeats=1)
    agree = abs(lp20[0] - r20.dollars) <= 1e-6 * max(1.0, abs(r20.dollars))
    emit("exact_flow_20k", dt_flow, f"dollars={r20.dollars:.2f}")
    emit("exact_lp_20k", dt_lp, f"dollars={lp20[0]:.2f};agree={agree}")

    # scale stability: LRU regret at 20k vs 100k requests
    tr20 = Trace(ids=ids20, sizes=np.ones(N))
    lru20 = regret(simulate("lru", tr20, costs, float(B)).dollars, r20.dollars)

    ids100 = rng.integers(0, N, 100_000).astype(np.int32)
    (r100, dt100) = timed(lambda: exact_opt_uniform(ids100, costs, B),
                          repeats=1)
    tr100 = Trace(ids=ids100, sizes=np.ones(N))
    lru100 = regret(simulate("lru", tr100, costs, float(B)).dollars,
                    r100.dollars)
    emit("exact_flow_100k", dt100,
         f"lru_regret_20k={lru20:.4f};lru_regret_100k={lru100:.4f};"
         f"drift={abs(lru100 - lru20):.4f}")

    # parametric budget sweep: 16 budgets, one warm-started SSP run
    budgets = np.linspace(4, 64, 16).astype(np.int64)
    (sweep, dt_sweep) = timed(
        lambda: exact_opt_uniform_sweep(ids100, costs, budgets), repeats=1)
    (per_budget, dt_ind) = timed(
        lambda: [exact_opt_uniform(ids100, costs, int(b)).dollars
                 for b in budgets], repeats=1)
    rel = max(abs(d - r) / max(1.0, abs(r))
              for d, r in zip(sweep.dollars, per_budget))
    speedup = dt_ind / dt_sweep
    assert rel <= 1e-6, f"sweep dollars diverge from per-budget: rel={rel:.2e}"
    assert speedup >= 5.0, \
        f"parametric sweep only {speedup:.1f}x over independent solves"
    emit("exact_sweep_16budgets_100k", dt_sweep,
         f"independent_s={dt_ind:.2f};speedup={speedup:.1f}x;"
         f"max_rel_err={rel:.1e};budgets={budgets[0]}..{budgets[-1]}")
    return dict(lru20=lru20, lru100=lru100, sweep_speedup=float(speedup))


if __name__ == "__main__":
    main()
