"""Online dollar-governor vs every fixed policy on the regime-shift trace.

The canonical governance scenario (DESIGN.md §8): the price vector flips
across s* mid-trace (fee-dominated -> egress-dominated), so no fixed
policy wins both phases — recency (LRU) wins while misses cost ~f, the
cost-aware GDSF wins once the bill is byte-weighted. The governor replays
every request against the metadata-only shadow panel ($0 of extra egress,
asserted via per-consumer meters) and hot-swaps the live policy when a
shadow's windowed dollars undercut the incumbent.

Emits per-policy realized dollars + regret vs the best fixed policy in
hindsight, the governed run's dollars/regret/swaps, and the shadow-panel
zero-egress check; also exports the governed run's metrics registry to
`benchmarks/out/governor_metrics.json`.
"""
from __future__ import annotations

from repro.egress.cache import ONLINE_POLICIES
from repro.online import MetricsRegistry
from repro.online.scenario import (regime_shift_scenario, run_fixed,
                                   run_governed)
from .common import OUT_DIR, emit, timed


def run_panel(n_phase=5000, seed=0, window=400, hysteresis=0.1):
    sc = regime_shift_scenario(n_phase=n_phase, seed=seed)
    fixed = {p: run_fixed(sc, p) for p in ONLINE_POLICIES}
    metrics = MetricsRegistry()
    governed, gov = run_governed(sc, window=window, hysteresis=hysteresis,
                                 auditor_window=4 * window, metrics=metrics)
    best = min(fixed.values(), key=lambda r: r["dollars"])
    store = gov.cache.store
    per_consumer = store.consumer_snapshot()
    shadow_extra = store.meter.dollars - per_consumer["governed"]["dollars"]
    window_audit = gov.audit()
    return dict(scenario=dict(requests=sc.num_requests, flip_at=sc.flip_at,
                              price_a=sc.price_a.name, price_b=sc.price_b.name,
                              capacity=sc.capacity_bytes),
                fixed=fixed, governed=governed, best_fixed=best,
                shadow_extra_dollars=shadow_extra,
                window_audit_regret=(window_audit.dollar_regret
                                     if window_audit else None),
                metrics=metrics)


def main():
    res, dt = timed(run_panel, repeats=1)
    best = res["best_fixed"]
    for p, r in res["fixed"].items():
        reg = (r["dollars"] - best["dollars"]) / best["dollars"]
        emit(f"governor_fixed_{p}", 0.0,
             f"dollars={r['dollars']:.6f};regret_vs_best={reg:.3f};"
             f"hit_rate={r['hit_rate']:.3f}")
    g = res["governed"]
    greg = (g["dollars"] - best["dollars"]) / best["dollars"]
    emit("governor_governed", dt,
         f"dollars={g['dollars']:.6f};regret_vs_best={greg:.3f};"
         f"best_fixed={best['policy']};swaps={len(g['swaps'])};"
         f"final={g['final_policy']}")
    emit("governor_within_10pct", 0.0, f"ok={greg <= 0.10}")
    emit("governor_shadow_zero_egress", 0.0,
         f"extra_dollars={res['shadow_extra_dollars']:.2e};"
         f"ok={abs(res['shadow_extra_dollars']) < 1e-12}")
    if res["window_audit_regret"] is not None:
        emit("governor_window_audit", 0.0,
             f"regret={res['window_audit_regret']:.3f}")
    res["metrics"].write_json(OUT_DIR / "governor_metrics.json")
    return res


if __name__ == "__main__":
    from . import common
    common.reset_records()
    main()
    common.write_json("governor")
