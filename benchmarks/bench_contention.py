"""Fig. 2 — contention frontier.

With N_exp expensive objects, GDSF's regret is large while B < N_exp and
collapses to ~0 exactly at B = N_exp: once the expensive working set fits,
greedy cost-ranking is optimal (paper: 0.23-0.69 before, 0.0002 at the
frontier). Exact OPT reference, uniform pages.

The whole budget axis is computed parametrically: ONE warm-started SSP run
(`exact_opt_uniform_sweep`) replaces the per-budget exact solves, and the
GDSF side replays every budget in one compiled device program (`sweep_jax`).
"""
from __future__ import annotations

import numpy as np

from repro.core import exact_opt_uniform_sweep, regret
from repro.core.policies_jax import sweep_jax
from .common import emit, timed


def run_frontier(n_exp=16, n_cheap=64, T=6000, seed=0, ratio=1e6):
    rng = np.random.default_rng(seed)
    N = n_exp + n_cheap
    # expensive objects: moderately popular; cheap: very popular
    p = np.concatenate([np.full(n_exp, 0.5 / n_exp),
                        np.full(n_cheap, 0.5 / n_cheap)])
    ids = rng.choice(N, size=T, p=p).astype(np.int32)
    costs = np.concatenate([np.full(n_exp, ratio), np.full(n_cheap, 1.0)])
    budgets = np.arange(2, n_exp + 8)
    opt = exact_opt_uniform_sweep(ids, costs, budgets)          # one solve
    gdsf = sweep_jax("gdsf", ids, costs[None, :], budgets,      # one program
                     num_objects=N)[0]
    out = [(int(B), regret(float(d), float(o)))
           for B, d, o in zip(budgets, gdsf, opt.dollars)]
    return out, n_exp


def main():
    (rows, n_exp), dt = timed(run_frontier, repeats=1)
    below = [r for B, r in rows if B <= n_exp]
    # NOTE (reproduction nuance, EXPERIMENTS.md §Claims): under the
    # mandatory-insertion semantics of eq. (2) — the fetched object occupies
    # a slot while served — every streaming cheap miss displaces a resident,
    # so the collapse lands at B = N_exp + 1 (the +1 is the serving scratch
    # slot). The paper reports the collapse "exactly at B = N_exp", i.e. a
    # bypass-admission cache model; the phenomenon and magnitudes match.
    frontier = dict(rows)[n_exp + 1]
    past = [r for B, r in rows if B > n_exp + 1]
    emit("fig2_contention_frontier", dt,
         f"n_exp={n_exp};regret_below_med={np.median(below):.4f};"
         f"regret_at_frontier={frontier:.6f};"
         f"regret_past_med={np.median(past):.6f}")
    return {"rows": rows, "n_exp": n_exp,
            "below": float(np.median(below)), "at": float(frontier)}


if __name__ == "__main__":
    main()
