"""Serving example: batched greedy decoding with an egress-billed prefix
cache. Repeated prompts re-fetch their prefix KV from cloud storage unless
the dollar-aware cache retains them; the audit scores the realized bill
against the exact offline reference.

    PYTHONPATH=src python examples/serve_with_egress_cache.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("gemma3-4b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, prefix_cache_bytes=1 << 22,
                         policy="gdsf", govern=True, governor_window=8)

    rng = np.random.default_rng(0)
    # a few hot prompts (shared prefixes) + a stream of cold ones, served in
    # rounds so repeats of a hot prefix touch the egress-billed prefix cache
    hot = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
           for _ in range(3)]
    done = []
    rid = 0
    for round_ in range(6):
        reqs = [Request(rid + i, h, max_new_tokens=4)
                for i, h in enumerate(hot)]
        rid += len(hot)
        cold = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        reqs.append(Request(rid, cold, max_new_tokens=4)); rid += 1
        done += engine.serve(reqs)
    print(f"served {len(done)} requests; sample output: "
          f"{done[0].output.tolist()}")
    print("\n--- prefix-cache egress audit ---")
    print(engine.audit().summary())
    print(f"store meter: {engine.store.meter.snapshot()}")
    print("\n--- online governance ---")
    win = engine.governor.audit()
    if win is not None:
        print(win.summary())
    gov = engine.governor.snapshot()
    print(f"governor: policy={gov['policy']} swaps={len(gov['swaps'])} "
          f"shadow $: " + ", ".join(f"{p}={s['dollars']:.6f}"
                                    for p, s in gov['shadow'].items()))


if __name__ == "__main__":
    main()