"""End-to-end driver: train a ~100M-param xLSTM for a few hundred steps on
CPU, with the full production substrate engaged:

  * data shards fetched from a billing-faithful ObjectStore through the
    dollar-aware EgressCache (the paper's technique in the data path),
  * AdamW, grad microbatching, per-layer remat,
  * atomic checkpoints + crash-resume,
  * a final egress audit against the exact offline reference.

    PYTHONPATH=src python examples/train_100m.py --steps 300

(--smoke trains the reduced config in seconds; the default 100M config is
minutes on this CPU.)
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.egress.cache import EgressCache
from repro.egress.store import ObjectStore
from repro.models.registry import get_model
from repro.train.data import DataPipeline, ShardedTokenDataset
from repro.train.driver import DriverConfig, TrainDriver
from repro.train.optim import OptimizerConfig, make_optimizer
from repro.train.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="gdsf")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m", smoke=args.smoke)
    model = get_model(cfg)
    print(f"arch: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

    params = model.init(jax.random.key(0))
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    opt = make_optimizer(OptimizerConfig(name="adamw", lr=3e-4))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, microbatches=2))

    # billing-faithful data path (the paper's substrate)
    store = ObjectStore("gcs_internet")
    ds = ShardedTokenDataset(store, num_shards=64,
                             shard_tokens=args.batch * args.seq * 4,
                             vocab=cfg.vocab_size).register()
    cache = EgressCache(store, capacity_bytes=8 * args.batch * args.seq * 4 * 4,
                        policy=args.policy)
    pipe = DataPipeline(ds, cache, batch_size=args.batch, seq_len=args.seq)

    with tempfile.TemporaryDirectory() as ckdir:
        driver = TrainDriver(
            DriverConfig(checkpoint_dir=ckdir, checkpoint_every=100,
                         max_steps=args.steps),
            step, params, opt_state, pipe)
        if driver.resume():
            print(f"resumed from step {driver.step}")
        out = driver.run()
        print(f"\ntrained {out['steps']} steps; "
              f"loss {driver.losses[0]:.3f} -> {out['final_loss']:.3f}")

    print("\n--- egress audit (paper's offline reference) ---")
    print(driver.pipeline.cache.audit().summary())
    print(f"store meter: {store.meter.snapshot()}")


if __name__ == "__main__":
    main()