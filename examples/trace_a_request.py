"""Trace a request: every dollar of a governed serving run, explained.

Runs the governed ServeEngine (egress-billed prefix cache + dollar
governor) with the full obs stack attached — span tracer, decision event
log, metrics registry with s*-centered histograms — then:

  * prints the span tree of one request (serve.request -> cache.get ->
    store.get) with per-span dollar attribution and regime tags,
  * proves billing faithfulness: the fsum of `store.get` span dollars for
    the prefix-cache consumer equals that consumer's BillingMeter total,
    and the event log's lifetime `miss` dollars equal it bit-for-bit,
  * writes the exportable artifacts: `obs.json` (the full governance +
    obs snapshot), `trace.chrome.json` (Chrome trace-event format — load
    it in Perfetto / chrome://tracing), and `metrics.prom` (Prometheus
    text exposition).

    PYTHONPATH=src python examples/trace_a_request.py --out obs_out

CI runs exactly this and validates `obs.json` against
tests/schemas/obs.json (see .github/workflows/ci.yml).
"""
import argparse
import json
import pathlib

import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import get_model
from repro.obs import EventLog, MetricsRegistry, Tracer
from repro.serve.engine import Request, ServeEngine


def span_tree(tracer: Tracer, root) -> list[str]:
    """Render a finished span subtree, dollars annotated."""
    by_parent: dict = {}
    for sp in tracer.spans():
        by_parent.setdefault(sp.parent_id, []).append(sp)
    lines = []

    def walk(sp, depth):
        a = sp.attrs or {}
        extra = ""
        if "dollars" in a:
            extra = f"  ${a['dollars']:.9f} ({a.get('regime', '?')})"
        elif "hit" in a:
            extra = f"  hit={a['hit']}"
        lines.append(f"{'  ' * depth}{sp.name} [{sp.dur * 1e6:.0f}us]"
                     f"{extra}")
        for ch in by_parent.get(sp.span_id, []):
            walk(ch, depth + 1)

    walk(root, 0)
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="directory for obs.json / trace.chrome.json / "
                         "metrics.prom (default: no files written)")
    args = ap.parse_args()

    tracer = Tracer(max_spans=100_000)
    events = EventLog(100_000)
    metrics = MetricsRegistry()

    cfg = get_config("gemma3-4b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, prefix_cache_bytes=1 << 22,
                         policy="gdsf", govern=True, governor_window=8,
                         metrics=metrics, tracer=tracer, events=events)

    rng = np.random.default_rng(0)
    hot = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
           for _ in range(3)]
    rid = 0
    for round_ in range(5):
        reqs = [Request(rid + i, h, max_new_tokens=4)
                for i, h in enumerate(hot)]
        rid += len(reqs)
        engine.serve(reqs)

    # ---- one request, explained -------------------------------------------
    req_spans = tracer.spans(name="serve.request")
    print(f"--- span tree of request rid={req_spans[-1].attrs['rid']} ---")
    print("\n".join(span_tree(tracer, req_spans[-1])))

    # ---- billing faithfulness ---------------------------------------------
    meter = engine.cache.meter
    span_dollars = tracer.dollars(name="store.get",
                                  consumer=engine.cache.consumer)
    event_dollars = events.dollars_billed("miss")
    print("\n--- billing faithfulness ---")
    print(f"prefix-cache meter      $ {meter.dollars:.12f}")
    print(f"sum of store.get spans  $ {span_dollars:.12f}")
    print(f"event log miss dollars  $ {event_dollars:.12f}")
    assert abs(span_dollars - meter.dollars) <= 1e-12 * max(1.0, meter.dollars)
    assert event_dollars == meter.dollars   # same-order accrual: bit-equal
    c = events.counts
    print(f"decisions: {c['hit']} hits, {c['miss']} misses, "
          f"{c['admit']} admits, {c['evict']} evicts "
          f"(${events.dollars_at_stake('hit'):.9f} saved by hits)")

    # ---- artifacts --------------------------------------------------------
    if args.out:
        out = pathlib.Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        snap = engine.governance_snapshot()
        (out / "obs.json").write_text(
            json.dumps(snap, indent=2, sort_keys=True) + "\n")
        tracer.write_chrome_trace(out / "trace.chrome.json")
        metrics.write_prometheus(out / "metrics.prom")
        print(f"\nwrote {out / 'obs.json'}, {out / 'trace.chrome.json'}, "
              f"{out / 'metrics.prom'}")


if __name__ == "__main__":
    main()
