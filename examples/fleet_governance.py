"""Fleet governance, end to end: four edge hosts, one quorum swap.

Replays the partitioned regime-shift scenario (DESIGN.md §10): four hosts
hash-partition a trace whose price vector flips across s* = f/e mid-
stream. Each host replays its partition against a metadata-only shadow
panel, closes event-time windows as its watermark advances, and gossips
`WindowDelta`s over a faulty in-process network (drops, duplicates,
reordering, delays). The coordinator quorum-swaps the fleet-wide policy
when a majority of the shadow-dollar-weighted votes agrees — then the
fleet's realized bill is reconciled three independent ways:

  * fsum over per-node BillingMeters  (what the hosts were billed)
  * fsum over per-node exact audits   (what the offline reference saw)
  * per-node wire-log replays         (what crossed the wire, re-accrued)

all bit-equal, and the governed fleet lands within 10% of the best fixed
policy chosen in hindsight.

    PYTHONPATH=src python examples/fleet_governance.py
"""
import math

from repro.egress.cache import EgressCache, ONLINE_POLICIES
from repro.fleet import Fleet, SimNetwork, hash_partition
from repro.online.scenario import regime_shift_scenario

N = 4
SCENARIO = dict(n_phase=3000, seed=0, n_big_active=12, big_bytes=1 << 18)


def run_fixed(sc, policy):
    store = sc.make_store()
    caches = [EgressCache(store, sc.capacity_bytes / N, policy,
                          consumer=f"edge{i}") for i in range(N)]
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        caches[hash_partition(key, N)].get(key)
    return math.fsum(c.meter.dollars for c in caches)


def main():
    sc = regime_shift_scenario(**SCENARIO)
    print(f"trace: {sc.num_requests} requests over {N} hosts, "
          f"price flips {sc.price_a.name} -> {sc.price_b.name} "
          f"at t={sc.flip_at}")

    fixed = {p: run_fixed(sc, p) for p in ONLINE_POLICIES}
    best = min(fixed, key=fixed.get)
    print("\nfixed-policy fleets (hindsight):")
    for p, d in sorted(fixed.items(), key=lambda kv: kv[1]):
        mark = "  <- best fixed" if p == best else ""
        print(f"  {p:5s} ${d:.6f}{mark}")

    net = SimNetwork(seed=3, drop=0.25, duplicate=0.3, reorder=0.5,
                     max_delay=2)
    store = sc.make_store()
    fleet = Fleet(store=store, n_nodes=N,
                  capacity_bytes=sc.capacity_bytes / N, policy="lru",
                  window_span=400.0, max_skew=32.0, gossip_every=100,
                  network=net)
    for t, key in enumerate(sc.keys):
        if t == sc.flip_at:
            store.set_price(sc.price_b)
        fleet.access(key, event_time=t)
    converged = fleet.flush()

    print(f"\ngoverned fleet (starts lru, faulty network):")
    for s in fleet.swaps:
        print(f"  window {s.window_id}: {s.old_policy} -> {s.new_policy} "
              f"({s.mode}, round {s.round})")
        for h, (vote, weight) in sorted(s.votes.items()):
            print(f"    {h}: votes {vote:5s} weight=${weight:.6f}")
    ns = net.snapshot()
    print(f"  network: {ns['sent']} sent, {ns['dropped']} dropped, "
          f"{ns['duplicated']} duplicated, {ns['reordered']} reordered; "
          f"converged={converged}")

    meters = fleet.dollars()
    audits = math.fsum(a.observed_dollars for a in fleet.audits().values())
    replays = math.fsum(n.replayed_dollars() for n in fleet.nodes)
    print(f"\nbilling identity (bit-equal):")
    print(f"  fsum(node meters)   ${meters!r}")
    print(f"  fsum(node audits)   ${audits!r}")
    print(f"  fsum(wire replays)  ${replays!r}")
    assert meters == audits == replays

    reg = (meters - fixed[best]) / fixed[best]
    print(f"\ngoverned ${meters:.6f} vs best fixed ({best}) "
          f"${fixed[best]:.6f}: regret {reg:+.1%} (within 10%: "
          f"{reg <= 0.10})")
    assert reg <= 0.10
    assert {n.cache.policy for n in fleet.nodes} == {fleet.policy}
    print(f"unanimous fleet policy: {fleet.policy}")


if __name__ == "__main__":
    main()
