"""Quickstart: the paper in five minutes on a laptop.

1. Build a workload (synthetic Zipf trace), price it under real cloud
   billing (eq. 1), and locate the GET-fee/egress crossover s* (eq. 3).
2. Compute the EXACT offline dollar-optimum (interval LP == min-cost flow).
3. Score LRU vs cost-aware GDSF in dollars against it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (PRICE_VECTORS, Trace, exact_opt_uniform,
                        heterogeneity, miss_costs, regret, simulate,
                        zipf_trace)


def main():
    print("=== cloud-egress caching quickstart ===\n")
    # a page-cache workload: uniform 4 KiB pages, heterogeneous miss costs
    # (same-region vs cross-region objects — cost varies, size doesn't)
    rng = np.random.default_rng(0)
    n_objects, T, B = 200, 8000, 24
    ids = rng.choice(n_objects, size=T,
                     p=(lambda p: p / p.sum())(
                         np.arange(1, n_objects + 1.) ** -0.9)).astype(np.int32)
    costs = np.exp(rng.normal(0, 2.0, n_objects))   # heterogeneous $ / miss
    tr = Trace(ids=ids, sizes=np.ones(n_objects), name="quickstart")

    H = heterogeneity(ids, costs)
    print(f"workload: {T} requests over {n_objects} pages, budget {B} pages")
    print(f"miss-cost heterogeneity H = {H:.2f}\n")

    for pv in PRICE_VECTORS.values():
        print(f"  {pv.name:16s} GET=${pv.get_fee:.2e}  "
              f"egress=${pv.egress_per_byte * 1e9:.3f}/GB  "
              f"crossover s* = {pv.crossover_bytes:,.0f} B")
    print()

    opt = exact_opt_uniform(ids, costs, B)
    print(f"exact offline dollar-optimum: ${opt.dollars:,.2f} "
          f"(no-cache ${opt.total_no_cache:,.2f}, "
          f"{opt.hits} retained reuses)\n")

    for policy in ("lru", "lfu", "gds", "gdsf", "belady", "cost_belady"):
        r = simulate(policy, tr, costs, float(B))
        print(f"  {policy:12s} ${r.dollars:10,.2f}   "
              f"dollar-regret {regret(r.dollars, opt.dollars):6.3f}   "
              f"hit-rate {r.hits / tr.num_requests:.3f}")
    print("\ncost-blind LRU leaves money on the table; GDSF buys most of "
          "it back (paper Fig. 1).")


if __name__ == "__main__":
    main()