"""Policy audit: sweep (policy x price-vector x budget) on the JAX replay
engine and bracket everything against the exact reference — the paper's
Table-1 workflow as a one-command operational tool. The whole sweep is
published through the online metrics registry and exported as JSON
(`benchmarks/out/policy_audit_metrics.json`).

    PYTHONPATH=src python examples/policy_audit.py
"""
import pathlib

import numpy as np

from repro.core import (PRICE_VECTORS, exact_opt_uniform, heterogeneity,
                        miss_costs, twemcache_like)
from repro.core.policies_jax import sweep_jax
from repro.online import MetricsRegistry

OUT = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" / "out"


def main():
    metrics = MetricsRegistry()
    tr = twemcache_like(n_requests=8000, seed=1)
    # page-cache view: audit the *cost* structure with uniform pages
    budgets = np.array([32, 64, 128, 256])
    names = list(PRICE_VECTORS)
    cost_matrix = np.stack([miss_costs(tr.sizes, PRICE_VECTORS[n])
                            for n in names])

    print("trace: twemcache-like,", tr.num_requests, "requests,",
          tr.num_objects, "objects, mean size",
          f"{tr.access_sizes().mean():.0f} B")
    print(f"\n{'price':16s} {'s*':>8s} {'H':>6s} | dollars by budget "
          f"{budgets.tolist()} (gdsf)")
    gdsf = sweep_jax("gdsf", tr.ids, cost_matrix, budgets,
                     num_objects=tr.num_objects)
    lru = sweep_jax("lru", tr.ids, cost_matrix, budgets,
                    num_objects=tr.num_objects)
    for i, n in enumerate(names):
        pv = PRICE_VECTORS[n]
        H = heterogeneity(tr.ids, cost_matrix[i])
        cells = " ".join(f"{d:9.4f}" for d in gdsf[i])
        print(f"{n:16s} {pv.crossover_bytes:8.0f} {H:6.2f} | {cells}")
        metrics.set_gauge(f"audit.{n}.sstar_bytes", pv.crossover_bytes)
        metrics.set_gauge(f"audit.{n}.heterogeneity", H)
        for k, b in enumerate(budgets):
            metrics.observe(f"audit.{n}.gdsf_dollars", float(gdsf[i][k]),
                            step=int(b))
            metrics.observe(f"audit.{n}.lru_dollars", float(lru[i][k]),
                            step=int(b))

    print("\nexact reference at B=64 (first price vector):")
    opt = exact_opt_uniform(tr.ids, cost_matrix[0], 64)
    print(f"  OPT ${opt.dollars:.4f}  vs gdsf ${gdsf[0][1]:.4f} "
          f"vs lru ${lru[0][1]:.4f}")
    metrics.set_gauge(f"audit.{names[0]}.opt_dollars_B64", opt.dollars)
    path = metrics.write_json(OUT / "policy_audit_metrics.json")
    print(f"\nmetrics registry exported to {path}")


if __name__ == "__main__":
    main()